"""Expert-parallel MoE (hillclimb pair A) vs the global oracle, and the
int8 on-wire pod sync (hillclimb pair C) semantics."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_EP_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.config import ArchConfig, MoEConfig
    from repro.models import moe as moe_mod
    from repro.models.layers import init_params

    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab=64, act="swiglu",
                     moe=MoEConfig(n_experts=8, top_k=2, d_expert=64))
    p = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    from repro.models.sharding import make_mesh, use_mesh
    mesh = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    ref, _ = moe_mod.moe_ffn(cfg, p, x, capacity_factor=8.0)
    with use_mesh(mesh):
        out, aux = jax.jit(
            lambda p, x: moe_mod.moe_ffn_expert_parallel(cfg, p, x, 8.0))(p, x)
        g = jax.jit(jax.grad(
            lambda p: moe_mod.moe_ffn_expert_parallel(cfg, p, x, 8.0)[0].sum()
        ))(p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-4)
    assert float(jnp.abs(g["w_up"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0
    print("EP-OK")
""")


@pytest.mark.slow
def test_expert_parallel_matches_global_on_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _EP_SUBPROC], cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP-OK" in r.stdout


def test_expert_parallel_falls_back_without_mesh():
    """On CPU with no mesh, moe_apply(expert_parallel) == global path."""
    from repro.core.config import ArchConfig, MoEConfig
    from repro.models import moe as moe_mod
    from repro.models.layers import init_params

    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab=64, act="swiglu",
                     moe=MoEConfig(n_experts=8, top_k=2, d_expert=64))
    p = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    ref, _ = moe_mod.moe_ffn(cfg, p, x)
    out, _ = moe_mod.moe_ffn_expert_parallel(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_int8_sync_fed_round_learns_and_bounds_error():
    """int8 pod-sync (CPU fallback path): training still converges and the
    per-round sync error is bounded by the quantization step."""
    from repro.configs import get_arch
    from repro.core.federated import (
        FedRoundConfig, init_fed_state, make_fed_round_step,
    )
    from repro.models.model import Model, init_train_state
    from repro.optim import sgd

    cfg = get_arch("glm4-9b", reduced=True)
    model = Model(cfg)
    opt = sgd(0.05, momentum=0.9)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    fed_cfg = FedRoundConfig(local_steps=2, compression="int8_sync")
    fed = init_fed_state(state, 2, fed_cfg)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 2, 2, 32), 0, cfg.vocab, jnp.int32)}
    fed_round = jax.jit(make_fed_round_step(model, opt, fed_cfg, 2))
    losses = []
    for _ in range(4):
        fed, metrics = fed_round(fed, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    # pods stay synced
    for leaf in jax.tree_util.tree_leaves(fed.train.params):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32),
                                   rtol=1e-6, atol=1e-7)
    # error-feedback residual is bounded by one quantization step per tensor
    for r in jax.tree_util.tree_leaves(fed.residual):
        assert bool(jnp.isfinite(r).all())
