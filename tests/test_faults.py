"""Fault-tolerant rounds (cfg.faults / cfg.checkpoint — docs/faults.md).

* parity: all probabilities 0 => bit-identical params + identical metric
  keys vs a config with no faults block, on every engine, with zero
  extra retraces of the batched cohort program;
* graceful degradation: survivors-only FedAvg matches a hand-computed
  oracle under dropout; NaN-injected / norm-outlier updates never reach
  the server params; crashes and deadline misses zero-weight out;
* checkpoint/resume: kill-and-resume continues bit-identically for the
  synchronous engines (params AND the next checkpoint file), including
  the error-feedback residual stores of the compressed fast path;
* async: failures retry with exponential backoff, counters land in the
  per-aggregation metrics, runaway failure rates raise loudly, and
  resume continues the remaining buffer aggregations.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.batched import cohort_trace_count
from repro.core.config import Config, FaultConfig, validate_fault_config
from repro.core.rounds import Trainer, update_is_valid, _poison_update
from repro.core.server import Server
from repro.data.fed_data import build_federated_data
from repro.models.registry import get_model
from repro.simulation.heterogeneity import FaultInjector


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _make_trainer(execution, faults=None, resources=None, ckpt=None,
                  comp="none", rounds=3, server_cls=Server):
    cfg = Config.make({
        "model": "linear",
        "data": {"dataset": "synthetic", "num_clients": 8, "batch_size": 32},
        "server": {"rounds": rounds, "clients_per_round": 5, "test_every": 0},
        "client": {"local_epochs": 2, "lr": 0.1, "compression": comp},
        "resources": {"execution": execution, **(resources or {})},
        "tracking": {"enabled": False},
        "faults": faults or {},
        "checkpoint": ckpt or {},
    })
    model = get_model("linear")
    fed = build_federated_data(cfg.data)
    trainer = Trainer(cfg, model, fed, server=server_cls(model, cfg, fed.test))
    trainer.server.params = model.init(jax.random.PRNGKey(cfg.seed))
    return trainer


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def _params_equal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a), _leaves(b)))


ASYNC_RES = {"buffer_size": 3, "max_concurrency": 5}


# ---------------------------------------------------------------------------
# parity: faults disabled is byte-identical to no faults block at all
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("execution,resources", [
    ("sequential", None),
    ("batched", None),
    ("async", ASYNC_RES),
])
def test_faults_all_zero_is_bit_identical(execution, resources):
    r0 = _make_trainer(execution, resources=resources).run()
    r1 = _make_trainer(
        execution, resources=resources,
        faults={"dropout_prob": 0.0, "crash_prob": 0.0, "straggler_prob": 0.0,
                "nan_update_prob": 0.0, "max_update_norm": 0.0,
                "seed": 7}).run()
    assert _params_equal(r0["params"], r1["params"])
    # no fault accounting keys leak into a faults-off history
    for h0, h1 in zip(r0["history"], r1["history"]):
        assert set(h0) == set(h1)
        assert "dropped" not in h1 and "survivors" not in h1


def test_batched_faults_do_not_retrace():
    """Failures are handled in the weight vector / timing layer: the
    cohort program never changes shape, so rounds with dropout + NaN
    injection reuse the round-0 trace."""
    t = _make_trainer("batched", faults={"dropout_prob": 0.4,
                                         "nan_update_prob": 0.2, "seed": 1})
    t.run_round(0)
    traces_after_first = cohort_trace_count()
    for r in range(1, 4):
        t.run_round(r)
    assert cohort_trace_count() == traces_after_first


# ---------------------------------------------------------------------------
# graceful degradation: survivors-only FedAvg
# ---------------------------------------------------------------------------


def test_dropout_survivors_match_hand_computed_fedavg():
    """Zero-weighting + renormalization == plain FedAvg over the
    survivor subset: compare against a twin trainer that trains only the
    survivors and aggregates them directly (bit-identical, same order)."""
    faults = {"dropout_prob": 0.5, "seed": 11}
    tA = _make_trainer("sequential", faults=faults)
    tB = _make_trainer("sequential")
    mA = tA.run_round(0)

    selected = tB.server.selection(tB.fed_data.client_ids, 0)
    plans = {c: tA.faults.plan(c, 0) for c in selected}
    survivors = [c for c in selected if not plans[c].dropout]
    assert 0 < len(survivors) < len(selected)  # the draw actually drops
    assert mA["dropped"] == len(selected) - len(survivors)
    assert mA["survivors"] == len(survivors)
    payload = tB.server.distribution(selected)
    results = [tB.client(c).run_round(payload, 0) for c in survivors]
    tB.server.aggregation(results)
    assert _params_equal(tA.server.params, tB.server.params)


def test_batched_matches_sequential_under_faults():
    faults = {"dropout_prob": 0.3, "crash_prob": 0.2, "seed": 4}
    rs = _make_trainer("sequential", faults=faults).run()
    rb = _make_trainer("batched", faults=faults).run()
    for a, b in zip(_leaves(rs["params"]), _leaves(rb["params"])):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    # the plan-derived counters are engine-independent
    for hs, hb in zip(rs["history"], rb["history"]):
        for k in ("dropped", "crashed", "straggled", "survivors", "clients"):
            assert hs[k] == hb[k], k


@pytest.mark.parametrize("execution", ["sequential", "batched"])
def test_nan_injected_updates_never_reach_params(execution):
    t = _make_trainer(execution, faults={"nan_update_prob": 1.0})
    p0 = jax.tree_util.tree_map(np.array, t.server.params)
    r = t.run()
    assert _params_equal(p0, t.server.params)   # zero delta, not NaN
    for h in r["history"]:
        assert h["rejected"] == h["clients"]
        assert h["survivors"] == 0
        assert np.isnan(h["train_loss"])


@pytest.mark.parametrize("execution", ["sequential", "batched"])
def test_norm_outlier_guard_rejects_everything_at_tiny_bound(execution):
    t = _make_trainer(execution, faults={"max_update_norm": 1e-12})
    p0 = jax.tree_util.tree_map(np.array, t.server.params)
    r = t.run()
    assert _params_equal(p0, t.server.params)
    assert all(h["rejected"] == h["clients"] for h in r["history"])


def test_crash_drops_update_but_elapses_partial_time():
    # floor 0: with crash_prob=1 no cohort can satisfy the default
    # min_clients_per_round=1 floor (that raising is its own test above)
    t = _make_trainer("sequential", faults={"crash_prob": 1.0,
                                            "min_clients_per_round": 0})
    p0 = jax.tree_util.tree_map(np.array, t.server.params)
    m = t.run_round(0)
    assert m["crashed"] == m["clients"] and m["survivors"] == 0
    assert m["round_time"] > 0.0          # partial virtual time elapsed
    assert _params_equal(p0, t.server.params)


def test_straggler_slowdown_stretches_round_time():
    base = _make_trainer("sequential").run_round(0)
    slow = _make_trainer(
        "sequential",
        faults={"straggler_prob": 1.0,
                "straggler_slowdown": 10.0}).run_round(0)
    assert slow["straggled"] == slow["clients"]
    assert slow["survivors"] == slow["clients"]   # slow, but not failed
    assert slow["round_time"] > 2.0 * base["round_time"]


@pytest.mark.parametrize("execution", ["sequential", "batched"])
def test_round_deadline_zero_weights_misses_without_fault_probs(execution):
    """resources.round_deadline alone (no fault probabilities) activates
    the degradation path: every client misses an impossibly tight
    deadline, so the round completes with zero survivors and unchanged
    params."""
    t = _make_trainer(execution, resources={"round_deadline": 1e-12})
    p0 = jax.tree_util.tree_map(np.array, t.server.params)
    m = t.run_round(0)
    assert m["deadline_missed"] == m["clients"] and m["survivors"] == 0
    assert m["round_time"] <= 1e-12 * m["clients"]   # makespan caps there
    assert _params_equal(p0, t.server.params)


# ---------------------------------------------------------------------------
# min_clients_per_round floor
# ---------------------------------------------------------------------------


def test_min_clients_floor_triggers_reselection_and_survives():
    t = _make_trainer("sequential",
                      faults={"dropout_prob": 0.5, "seed": 2,
                              "min_clients_per_round": 3})
    m = t.run_round(0)
    assert m["survivors"] >= 3
    assert m["reselections"] >= 0


def test_min_clients_floor_unreachable_raises():
    t = _make_trainer("sequential",
                      faults={"dropout_prob": 0.98, "seed": 0,
                              "min_clients_per_round": 5})
    with pytest.raises(ValueError, match="min_clients_per_round"):
        t.run_round(0)


def test_min_clients_floor_above_cohort_size_rejected_at_init():
    with pytest.raises(ValueError, match="can never be met"):
        _make_trainer("sequential", faults={"dropout_prob": 0.1,
                                            "min_clients_per_round": 6})


# ---------------------------------------------------------------------------
# deterministic sampling + validation
# ---------------------------------------------------------------------------


def test_fault_injector_is_deterministic_per_client_round():
    inj = FaultInjector(FaultConfig(dropout_prob=0.4, crash_prob=0.3,
                                    straggler_prob=0.5, nan_update_prob=0.2,
                                    seed=9))
    a = [inj.plan(f"c{i}", r) for i in range(20) for r in range(5)]
    b = [inj.plan(f"c{i}", r) for i in range(20) for r in range(5)]
    assert a == b
    # a different seed decorrelates the draws
    other = FaultInjector(FaultConfig(dropout_prob=0.4, crash_prob=0.3,
                                      straggler_prob=0.5,
                                      nan_update_prob=0.2, seed=10))
    c = [other.plan(f"c{i}", r) for i in range(20) for r in range(5)]
    assert c != a
    # dropout/crash/nan are mutually exclusive on one (client, round)
    for p in a:
        assert p.dropout + p.crash + p.nan_update <= 1
        assert 0.0 <= p.crash_fraction <= 1.0


@pytest.mark.parametrize("bad,match", [
    ({"dropout_prob": 1.5}, "dropout_prob"),
    ({"crash_prob": -0.1}, "crash_prob"),
    ({"straggler_slowdown": 0.5}, "straggler_slowdown"),
    ({"max_update_norm": float("inf")}, "max_update_norm"),
    ({"min_clients_per_round": -1}, "min_clients_per_round"),
    ({"max_retries": -2}, "max_retries"),
    ({"retry_backoff": float("nan")}, "retry_backoff"),
])
def test_fault_config_validation_is_loud(bad, match):
    with pytest.raises(ValueError, match=match):
        validate_fault_config(FaultConfig(**bad))


def test_poison_and_guard_helpers():
    clean = {"w": np.ones((3, 2), np.float32), "b": np.zeros(2, np.float32)}
    assert update_is_valid(clean)
    bad = _poison_update(clean)
    assert not update_is_valid(bad)
    # norm bound: a clean update with norm sqrt(6) fails a bound of 1.0
    assert not update_is_valid(clean, max_norm=1.0)
    assert update_is_valid(clean, max_norm=10.0)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("execution,comp,faults", [
    ("sequential", "none", None),
    ("sequential", "stc", {"dropout_prob": 0.3, "seed": 5}),
    ("batched", "stc", {"dropout_prob": 0.3, "seed": 5}),
    ("batched", "int8", {"crash_prob": 0.3, "seed": 2}),
])
def test_kill_and_resume_is_bit_identical(tmp_path, execution, comp, faults):
    """Run A trains 4 rounds straight through; run B is killed after
    round 2 and resumed by a FRESH trainer from the checkpoint.  Both the
    final params and the step-4 checkpoint must match bit for bit —
    including the compressed fast path's error-feedback residuals and the
    fault sampler's decisions."""
    from repro.checkpoint.store import load_checkpoint

    dir_a, dir_b = str(tmp_path / "A"), str(tmp_path / "B")
    ra = _make_trainer(execution, faults=faults, comp=comp, rounds=4,
                       ckpt={"every": 2, "dir": dir_a}).run()

    tb = _make_trainer(execution, faults=faults, comp=comp, rounds=4,
                       ckpt={"every": 2, "dir": dir_b})
    for r in range(2):                      # ... killed after round 2
        tb.run_round(r)
        tb._maybe_checkpoint(r + 1)
    tc = _make_trainer(execution, faults=faults, comp=comp, rounds=4,
                       ckpt={"every": 2, "dir": dir_b})
    rc = tc.resume()

    assert _params_equal(ra["params"], rc["params"])
    assert len(rc["history"]) == 4
    cka = load_checkpoint(dir_a, 4)
    ckb = load_checkpoint(dir_b, 4)
    assert _params_equal(cka["server"]["params"], ckb["server"]["params"])
    assert [h["train_loss"] for h in cka["history"]] == \
        [h["train_loss"] for h in ckb["history"]]


def test_resume_with_wrong_engine_raises(tmp_path):
    d = str(tmp_path / "ck")
    _make_trainer("sequential", ckpt={"every": 2, "dir": d}, rounds=2).run()
    t = _make_trainer("batched", ckpt={"every": 2, "dir": d}, rounds=2)
    with pytest.raises(ValueError, match="same engine"):
        t.resume()


def test_checkpoint_sweeps_stale_tmp_and_lists_available_steps(tmp_path):
    from repro.checkpoint.store import (
        available_steps, load_checkpoint, save_checkpoint,
    )

    d = str(tmp_path / "ck")
    os.makedirs(d)
    stale = os.path.join(d, "killed_mid_write.tmp")
    with open(stale, "wb") as f:
        f.write(b"partial")
    save_checkpoint(d, {"x": 1}, step=2)
    save_checkpoint(d, {"x": 2}, step=4)
    assert not os.path.exists(stale)
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    assert available_steps(d) == [2, 4]
    with pytest.raises(FileNotFoundError, match=r"available steps: \[2, 4\]"):
        load_checkpoint(d, step=3)


def test_checkpoint_keep_gc(tmp_path):
    from repro.checkpoint.store import available_steps, save_checkpoint

    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, {"s": s}, step=s, keep=2)
    assert available_steps(d) == [4, 5]


# ---------------------------------------------------------------------------
# async engine: failures, retry, guard, resume
# ---------------------------------------------------------------------------


def test_async_dropout_retries_and_completes():
    t = _make_trainer("async", resources=ASYNC_RES,
                      faults={"dropout_prob": 0.3, "seed": 1,
                              "retry_backoff": 0.01})
    r = t.run()
    assert len(r["history"]) == 3
    totals = {k: sum(h[k] for h in r["history"])
              for k in ("dropped", "retried", "rejected")}
    assert totals["dropped"] > 0
    assert totals["retried"] > 0
    for leaf in _leaves(t.server.params):
        assert np.isfinite(leaf).all()


def test_async_nan_guard_rejects_and_redispatches():
    t = _make_trainer("async", resources=ASYNC_RES,
                      faults={"nan_update_prob": 0.3, "seed": 6,
                              "retry_backoff": 0.01})
    r = t.run()
    assert len(r["history"]) == 3
    assert sum(h["rejected"] for h in r["history"]) > 0
    for leaf in _leaves(t.server.params):
        assert np.isfinite(leaf).all()


def test_async_runaway_failure_rate_raises():
    t = _make_trainer("async", rounds=1,
                      resources={"buffer_size": 2, "max_concurrency": 2},
                      faults={"dropout_prob": 1.0, "max_retries": 1,
                              "retry_backoff": 0.001})
    with pytest.raises(ValueError, match="cannot make progress"):
        t.run()


def test_async_resume_continues_remaining_aggregations(tmp_path):
    d = str(tmp_path / "ck")
    t = _make_trainer("async", rounds=4, resources=ASYNC_RES,
                      ckpt={"every": 2, "dir": d})
    t.run()
    assert len(t.history) == 4
    tc = _make_trainer("async", rounds=4, resources=ASYNC_RES,
                       ckpt={"every": 2, "dir": d})
    rc = tc.resume(step=2)       # killed after the 2nd aggregation
    assert len(rc["history"]) == 4
    assert rc["history"][:2] == t.history[:2]   # restored verbatim
    for leaf in _leaves(tc.server.params):
        assert np.isfinite(leaf).all()


# ---------------------------------------------------------------------------
# FedBuff buffer accounting (satellite)
# ---------------------------------------------------------------------------


def test_fedbuff_buffered_ids_leftover_carry_and_state_roundtrip():
    from repro.core.strategies.fedbuff import FedBuffServer

    cfg = Config.make({
        "model": "linear",
        "data": {"dataset": "synthetic", "num_clients": 4},
        "resources": {"buffer_size": 5},
    })
    model = get_model("linear")
    fed = build_federated_data(cfg.data)
    srv = FedBuffServer(model, cfg, fed.test)
    srv.params = model.init(jax.random.PRNGKey(0))
    shapes = jax.tree_util.tree_map(np.shape, srv.params)

    def result(i):
        upd = jax.tree_util.tree_map(
            lambda s: np.full(s, 0.01, np.float32), shapes,
            is_leaf=lambda x: isinstance(x, tuple))
        return {"client_id": f"c{i}", "update": upd, "num_samples": 10,
                "train_time": float(i)}

    srv.aggregation([result(i) for i in range(3)])
    assert srv.buffered_client_ids() == ["c0", "c1", "c2"]  # sub-K: carried
    srv.aggregation([result(i) for i in range(3, 6)])       # 6 >= K=5
    assert srv.buffered_client_ids() == ["c5"]              # leftover carry

    # checkpoint round-trip preserves the leftover buffer
    state = srv.state_dict()
    srv2 = FedBuffServer(model, cfg, fed.test)
    srv2.load_state_dict(state)
    assert srv2.buffered_client_ids() == ["c5"]
    p_before = jax.tree_util.tree_map(np.array, srv2.params)
    srv2.finalize()
    assert srv2.buffered_client_ids() == []
    assert not _params_equal(p_before, srv2.params)   # flush applied it


# ---------------------------------------------------------------------------
# multi-pod fed_round guard (satellite)
# ---------------------------------------------------------------------------


def test_finite_pod_mean_zero_weights_bad_pods():
    from repro.core.federated import finite_pod_mean

    good = np.arange(12, dtype=np.float32).reshape(4, 3)
    tree = {"w": good.copy(), "b": np.ones((4, 2), np.float32)}
    tree["w"][1] = np.nan                     # pod 1 diverged
    out = finite_pod_mean(tree)
    keep = [0, 2, 3]
    np.testing.assert_allclose(np.asarray(out["w"]), good[keep].mean(axis=0))
    np.testing.assert_allclose(np.asarray(out["b"]), np.ones(2))
    # all-bad degrades to a zero delta instead of NaN
    allbad = {"w": np.full((2, 3), np.nan, np.float32)}
    np.testing.assert_array_equal(np.asarray(finite_pod_mean(allbad)["w"]),
                                  np.zeros(3))


def test_fed_round_config_skip_nonfinite_flag_exists():
    from repro.core.federated import FedRoundConfig
    assert FedRoundConfig().skip_nonfinite is False
    assert FedRoundConfig(skip_nonfinite=True).skip_nonfinite is True
