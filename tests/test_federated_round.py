"""Cross-pod federated round (repro.core.federated) semantics on CPU:
the vmapped fed_round_step must equal running each pod independently and
FedAvg-ing the deltas by hand."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.federated import FedRoundConfig, init_fed_state, make_fed_round_step
from repro.models.model import Model, init_train_state
from repro.optim import sgd


def _setup(compression="none", pods=2, E=2):
    cfg = get_arch("glm4-9b", reduced=True)
    model = Model(cfg)
    opt = sgd(0.05, momentum=0.9)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    fed_cfg = FedRoundConfig(local_steps=E, compression=compression,
                             stc_sparsity=0.25)
    fed = init_fed_state(state, pods, fed_cfg)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (pods, E, B, S), 0, cfg.vocab, jnp.int32)}
    return model, opt, state, fed_cfg, fed, batch


def _manual_round(model, opt, state, fed_cfg, batch, pods):
    """Reference: train each pod separately, average deltas."""
    from repro.models.model import make_train_step
    step = make_train_step(model, opt, remat=True)
    deltas = []
    for p in range(pods):
        s = state
        for e in range(fed_cfg.local_steps):
            micro = {k: v[p, e] for k, v in batch.items()}
            s, _ = step(s, micro)
        deltas.append(jax.tree_util.tree_map(
            lambda n, o: n.astype(jnp.float32) - o.astype(jnp.float32),
            s.params, state.params))
    return jax.tree_util.tree_map(
        lambda *ds: sum(ds) / len(ds), *deltas)


def test_fed_round_equals_manual_fedavg():
    model, opt, state, fed_cfg, fed, batch = _setup()
    fed_round = jax.jit(make_fed_round_step(model, opt, fed_cfg, 2))
    new_fed, metrics = fed_round(fed, batch)
    agg = _manual_round(model, opt, state, fed_cfg, batch, 2)
    expected = jax.tree_util.tree_map(
        lambda s, a: s.astype(jnp.float32) + a, state.params, agg)
    got0 = jax.tree_util.tree_map(lambda x: x[0], new_fed.train.params)
    for e, g in zip(jax.tree_util.tree_leaves(expected),
                    jax.tree_util.tree_leaves(got0)):
        # atol covers XLA fusion/reduction-order drift between the vmapped
        # program and the per-pod Python loop (embedding scatter-add order)
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(e, np.float32),
                                   rtol=5e-3, atol=2e-3)


def test_fed_round_pods_stay_synced():
    model, opt, state, fed_cfg, fed, batch = _setup()
    fed_round = jax.jit(make_fed_round_step(model, opt, fed_cfg, 2))
    new_fed, _ = fed_round(fed, batch)
    for leaf in jax.tree_util.tree_leaves(new_fed.train.params):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_fed_round_with_stc_compression_learns():
    model, opt, state, fed_cfg, fed, batch = _setup(compression="stc")
    fed_round = jax.jit(make_fed_round_step(model, opt, fed_cfg, 2))
    losses = []
    for r in range(4):
        fed, metrics = fed_round(fed, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    # residual (error feedback) must be non-trivial
    rnorm = sum(float(jnp.abs(x).sum())
                for x in jax.tree_util.tree_leaves(fed.residual))
    assert rnorm > 0
