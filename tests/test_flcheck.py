"""flcheck: AST rules (firing + clean-twin fixtures), project rules on
synthetic config trees, CLI behavior, and the compiled-program contracts
(retrace budget + roofline ratchet demonstrably trip).

The fixture corpus lives in ``tests/fixtures/flcheck/`` — real files, so
the suite also proves the fixtures stay syntactically valid.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import RULES, lint_paths
from repro.analysis import contracts
from repro.analysis.lint import ProjectContext, find_root, parse_module
from repro.analysis.rules.config_rules import undocumented_config_fields

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "flcheck"


def lint_fixture(name: str):
    return lint_paths([str(FIXTURES / name)], root=str(FIXTURES),
                      project_rules=False)


def rule_counts(findings):
    out = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


# ---------------------------------------------------------------------------
# rule catalog
# ---------------------------------------------------------------------------


def test_rule_catalog_complete():
    expected = {"FLC101", "FLC102", "FLC201", "FLC202", "FLC203",
                "FLC204", "FLC301", "FLC401", "FLC402"}
    assert expected <= set(RULES)
    assert len(set(RULES)) >= 8
    for rule in RULES.values():
        assert rule.id and rule.summary and rule.hint


# ---------------------------------------------------------------------------
# host-sync rules (FLC101/FLC102)
# ---------------------------------------------------------------------------


def test_host_sync_fires():
    counts = rule_counts(lint_fixture("host_sync_fire.py"))
    assert counts.get("FLC101") == 3      # block_until_ready, device_get, .item()
    assert counts.get("FLC102") == 3      # float(), int(), np.asarray-in-trace


def test_host_sync_clean_twin():
    assert lint_fixture("host_sync_clean.py") == []


def test_findings_format():
    f = lint_fixture("host_sync_fire.py")[0]
    line = f.format()
    assert line.startswith(f"{f.path}:{f.line} {f.rule} ")
    assert "(hint: " in line


# ---------------------------------------------------------------------------
# traced-control rules (FLC201-FLC204)
# ---------------------------------------------------------------------------


def test_traced_control_fires():
    counts = rule_counts(lint_fixture("traced_fire.py"))
    assert counts.get("FLC201") == 1
    assert counts.get("FLC202") == 1
    assert counts.get("FLC203") == 1
    assert counts.get("FLC204") == 1


def test_traced_control_clean_twin():
    assert lint_fixture("traced_clean.py") == []


# ---------------------------------------------------------------------------
# jit hygiene (FLC301)
# ---------------------------------------------------------------------------


def test_jit_donation_fires():
    findings = lint_fixture("jit_fire.py")
    assert rule_counts(findings).get("FLC301") == 3
    assert {f.rule for f in findings} == {"FLC301"}


def test_jit_donation_clean_twin():
    # includes a documented '# flcheck: ignore[FLC301]' suppression
    assert lint_fixture("jit_clean.py") == []


# ---------------------------------------------------------------------------
# config contracts (FLC401/FLC402) on a synthetic tree
# ---------------------------------------------------------------------------

CONFIG_FIRE = '''\
from dataclasses import dataclass, field


@dataclass(frozen=True)
class FaultConfig:
    dropout_prob: float = 0.0
    seed: int = 0


@dataclass(frozen=True)
class Config:
    task_id: str = "task"
    faults: FaultConfig = field(default_factory=FaultConfig)


def validate_fault_config(cfg):
    if not 0 <= cfg.dropout_prob <= 1:
        raise ValueError("dropout_prob")


def validate_config(cfg):
    if not cfg.task_id:
        raise ValueError("task_id")
    validate_fault_config(cfg.faults)
'''

CONFIG_CLEAN = CONFIG_FIRE.replace(
    '        raise ValueError("dropout_prob")\n',
    '        raise ValueError("dropout_prob")\n'
    '    if not isinstance(cfg.seed, int):\n'
    '        raise ValueError("seed")\n')

DOC_FIRE = "`task_id` `faults` `dropout_prob`\n"
DOC_CLEAN = DOC_FIRE.rstrip() + " `seed`\n"


def _config_tree(tmp_path, config_src, doc):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "config.md").write_text(doc)
    core = tmp_path / "src" / "core"
    core.mkdir(parents=True)
    (core / "config.py").write_text(config_src)
    return tmp_path / "src"


def test_config_rules_fire(tmp_path):
    src = _config_tree(tmp_path, CONFIG_FIRE, DOC_FIRE)
    findings = lint_paths([str(src)], root=str(tmp_path))
    counts = rule_counts(findings)
    assert counts.get("FLC401") == 1      # FaultConfig.seed unvalidated
    assert counts.get("FLC402") == 1      # FaultConfig.seed undocumented
    assert all("seed" in f.message for f in findings)


def test_config_rules_clean_twin(tmp_path):
    src = _config_tree(tmp_path, CONFIG_CLEAN, DOC_CLEAN)
    assert lint_paths([str(src)], root=str(tmp_path)) == []


def test_undocumented_fields_helper_matches_repo():
    """The shared helper (used by scripts/check_docs.py) is clean on the
    real tree — the doc gate and FLC402 see the same source of truth."""
    info = parse_module(str(REPO / "src" / "repro" / "core" / "config.py"),
                        str(REPO))
    ctx = ProjectContext(root=str(REPO), modules=[info])
    assert undocumented_config_fields(ctx) == []


def test_repo_tree_is_flcheck_clean():
    assert lint_paths([str(REPO / "src" / "repro")], root=str(REPO)) == []


# ---------------------------------------------------------------------------
# suppressions + hot markers
# ---------------------------------------------------------------------------


def test_suppression_and_hot_marker(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(
        "import jax\n\n\n"
        "def fetch(xs):  # flcheck: hot\n"
        "    return jax.device_get(xs)\n\n\n"
        "def fetch_ok(xs):  # flcheck: hot\n"
        "    return jax.device_get(xs)  # flcheck: ignore[FLC101]  -- why\n")
    findings = lint_paths([str(p)], root=str(tmp_path),
                          project_rules=False)
    assert [f.rule for f in findings] == ["FLC101"]
    assert findings[0].line == 5          # only the unsuppressed sync


def test_find_root_locates_repo():
    assert find_root(str(FIXTURES)) == str(REPO)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _run_cli(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "flcheck.py"), *args],
        capture_output=True, text=True, cwd=str(REPO))


def test_cli_exit_codes():
    bad = _run_cli(str(FIXTURES / "jit_fire.py"))
    assert bad.returncode == 1
    assert "FLC301" in bad.stdout
    good = _run_cli(str(FIXTURES / "jit_clean.py"))
    assert good.returncode == 0, good.stdout + good.stderr


def test_cli_self_documenting():
    r = _run_cli("--help")
    assert r.returncode == 0
    for rule_id in RULES:
        assert rule_id in r.stdout


# ---------------------------------------------------------------------------
# layer 2: compiled-program contracts
# ---------------------------------------------------------------------------


def test_contracts_hold_on_current_program():
    report = contracts.check_contracts()
    assert report.ok, report.format()
    assert report.retraces == 0
    assert report.host_transfer_ops == []
    assert report.baseline is not None


def test_contracts_gate_trips(tmp_path):
    """One compile, two corrupted gates: a zero trace budget and a
    baseline recorded for a far smaller program must both be violations."""
    bogus = tmp_path / "baseline.json"
    bogus.write_text(json.dumps(
        {"flops": 1.0, "hbm_bytes": 1.0, "tolerance": 0.15}))
    report = contracts.check_contracts(baseline_path=str(bogus),
                                       trace_budget=0)
    assert not report.ok
    joined = "\n".join(report.violations)
    assert "retrace budget" in joined
    assert "roofline ratchet" in joined
    assert "flops" in joined and "hbm_bytes" in joined
    assert "FAILED" in report.format()


def test_contracts_missing_baseline_is_a_violation(tmp_path):
    report = contracts.check_contracts(
        baseline_path=str(tmp_path / "nope.json"))
    assert not report.ok
    assert any("no roofline baseline" in v for v in report.violations)


def test_committed_baseline_matches_fixed_shapes():
    with open(os.path.join(str(REPO), "scripts",
                           "roofline_baseline.json")) as f:
        base = json.load(f)
    assert base["program"]["clients"] == contracts.N_CLIENTS
    assert base["program"]["local_steps"] == contracts.LOCAL_STEPS
    assert base["tolerance"] == contracts.TOLERANCE
    assert base["flops"] > 0 and base["hbm_bytes"] > 0
