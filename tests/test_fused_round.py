"""Whole-round fusion (``resources.round_fusion``): parity, structure,
resume, and the satellite fixes that ride along (dense payload dtype
accounting, ``server.server_lr`` plumbing, ``tracking.round_sync``).

The fused path must be *indistinguishable* from the staged fast path in
results — bit-identical for ``none``/``stc`` compression, <= 1e-6 for
``int8`` (one fused program gives XLA more fusion freedom) — while
executing as ONE dispatch with ONE batched host fetch per round and zero
retraces across rounds.  The 8-device mesh leg runs in a subprocess that
owns ``--xla_force_host_platform_device_count`` (conftest asserts it is
never set globally).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

import repro as easyfl

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(fusion, extra=None, execution="batched", rounds=3):
    easyfl.reset()
    cfg = {
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 10, "batch_size": 32},
        "server": {"rounds": rounds, "clients_per_round": 5},
        "client": {"local_epochs": 2, "lr": 0.1},
        "resources": {"execution": execution, "round_fusion": fusion},
    }
    for k, v in (extra or {}).items():
        cfg.setdefault(k, {}).update(v)
    easyfl.init(cfg)
    res = easyfl.run()
    easyfl.reset()
    return res


def _assert_params(a, b, atol=0.0):
    for x, y in zip(jax.tree_util.tree_leaves(a["params"]),
                    jax.tree_util.tree_leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=0, atol=atol)


FAULTS = {"faults": {"dropout_prob": 0.25, "crash_prob": 0.15,
                     "nan_update_prob": 0.25, "max_update_norm": 100.0,
                     "seed": 7}}


# ---------------------------------------------------------------------------
# parity matrix: {none, stc, int8} x {faults on/off} x {flat, hierarchical}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", ["none", "stc", "int8"])
@pytest.mark.parametrize("faults", [False, True])
@pytest.mark.parametrize("topology", ["flat", "hierarchical"])
def test_fused_matches_staged(comp, faults, topology):
    extra = {"client": {"compression": comp},
             "resources": {"aggregation_topology": topology}}
    if faults:
        extra.update(FAULTS)
    fused = _run("auto", extra)
    staged = _run("off", extra)
    # int8: the fused program is one XLA computation, so reassociation
    # may differ by one float32 ulp; none/stc replicate bit for bit
    _assert_params(fused, staged, atol=1e-6 if comp == "int8" else 0.0)
    np.testing.assert_allclose(
        [h["train_loss"] for h in fused["history"]],
        [h["train_loss"] for h in staged["history"]], rtol=1e-6)
    np.testing.assert_allclose(
        [h["comm_up_bytes"] for h in fused["history"]],
        [h["comm_up_bytes"] for h in staged["history"]])
    if faults:
        for key in ("survivors", "dropped", "crashed", "rejected"):
            assert [h[key] for h in fused["history"]] == \
                [h[key] for h in staged["history"]]


def test_fused_matches_sequential():
    fused = _run("auto")
    seq = _run("off", execution="sequential")
    for x, y in zip(jax.tree_util.tree_leaves(fused["params"]),
                    jax.tree_util.tree_leaves(seq["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# structure: one trace, zero retraces, one dispatch + one fetch per round
# ---------------------------------------------------------------------------


def test_fused_one_dispatch_one_fetch_zero_retraces():
    from repro.core import batched

    easyfl.reset()
    easyfl.init({
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 10, "batch_size": 32},
        "server": {"rounds": 4, "clients_per_round": 5, "test_every": 0},
        "client": {"local_epochs": 2, "lr": 0.1},
        "resources": {"execution": "batched"},
    })
    t0 = batched.round_trace_count()
    d0, h0 = batched.dispatch_count(), batched.host_sync_count()
    easyfl.run()
    easyfl.reset()
    # one trace for the first round, zero retraces over rounds 2..4
    assert batched.round_trace_count() - t0 == 1
    assert batched.dispatch_count() - d0 == 4      # 1 per round
    assert batched.host_sync_count() - h0 == 4     # 1 batched fetch per round


# ---------------------------------------------------------------------------
# fallback is loud, "off" is honored, bad values refused
# ---------------------------------------------------------------------------


def test_ineligible_round_warns_once_and_falls_back():
    from repro.core.server import Server

    class CustomApply(Server):
        def apply_delta(self, delta, server_lr=None):
            super().apply_delta(delta, server_lr)

    easyfl.reset()
    easyfl.init({
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 6, "batch_size": 32},
        "server": {"rounds": 2, "clients_per_round": 4},
        "client": {"local_epochs": 1, "lr": 0.1},
        "resources": {"execution": "batched"},
    })
    easyfl.register_server(CustomApply)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        easyfl.run()
    easyfl.reset()
    hits = [w for w in caught
            if "round_fusion" in str(w.message)]
    assert len(hits) == 1                      # once per trainer, not per round
    assert "apply_delta override" in str(hits[0].message)


def test_round_fusion_off_uses_staged_path():
    from repro.core import batched

    t0 = batched.round_trace_count()
    _run("off", rounds=2)
    assert batched.round_trace_count() == t0   # fused program never built


def test_bad_round_fusion_value_rejected():
    easyfl.reset()
    with pytest.raises(ValueError, match="round_fusion"):
        easyfl.init({"model": "linear", "dataset": "synthetic",
                     "resources": {"round_fusion": "sometimes"}})
        easyfl.run()
    easyfl.reset()


# ---------------------------------------------------------------------------
# satellite: server_lr plumbing (staged + fused + sequential parity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fusion", ["auto", "off"])
def test_server_lr_batched_matches_sequential(fusion):
    extra = {"server": {"server_lr": 0.5}}
    bat = _run(fusion, extra)
    seq = _run("off", extra, execution="sequential")
    for x, y in zip(jax.tree_util.tree_leaves(bat["params"]),
                    jax.tree_util.tree_leaves(seq["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
    # and it actually deviates from the lr=1 run
    base = _run(fusion)
    deltas = [float(np.abs(np.asarray(x) - np.asarray(y)).max())
              for x, y in zip(jax.tree_util.tree_leaves(bat["params"]),
                              jax.tree_util.tree_leaves(base["params"]))]
    assert max(deltas) > 1e-4


def test_bad_server_lr_rejected():
    easyfl.reset()
    with pytest.raises(ValueError, match="server_lr"):
        easyfl.init({"model": "linear", "dataset": "synthetic",
                     "server": {"server_lr": 0.0}})
        easyfl.run()
    easyfl.reset()


# ---------------------------------------------------------------------------
# satellite: dense payload bytes use real dtype itemsize
# ---------------------------------------------------------------------------


def test_dense_update_bytes_uses_leaf_dtype():
    import jax.numpy as jnp

    from repro.core.rounds import dense_update_bytes

    tree = {"w": jnp.zeros((8, 4), jnp.float32),        # 32 * 4
            "h": jnp.zeros((10,), jnp.bfloat16),        # 10 * 2
            "q": jnp.zeros((6,), jnp.int8),             # 6 * 1
            "b": np.zeros((3,), np.float16)}            # 3 * 2
    assert dense_update_bytes(tree) == 32 * 4 + 10 * 2 + 6 * 1 + 3 * 2


def test_dense_round_reports_dtype_true_wire_bytes():
    res = _run("auto", rounds=1)
    # linear(64, 10): one (64, 10) f32 matrix + (10,) f32 bias per client
    per_client = (64 * 10 + 10) * 4
    assert res["history"][0]["comm_up_bytes"] == per_client * 5


# ---------------------------------------------------------------------------
# satellite: tracking.round_sync deferred finalize
# ---------------------------------------------------------------------------


def test_round_sync_false_matches_sync_run():
    extra = {"tracking": {"round_sync": False}, "server": {"test_every": 2}}
    deferred = _run("auto", extra, rounds=4)
    synced = _run("auto", {"server": {"test_every": 2}}, rounds=4)
    _assert_params(deferred, synced)
    assert len(deferred["history"]) == 4
    assert [sorted(h) for h in deferred["history"]] == \
        [sorted(h) for h in synced["history"]]
    np.testing.assert_allclose(
        [h["train_loss"] for h in deferred["history"]],
        [h["train_loss"] for h in synced["history"]])


@pytest.mark.parametrize("bad", [
    {"faults": {"dropout_prob": 0.5}},
    {"resources": {"round_deadline": 5.0}},
])
def test_round_sync_false_rejects_exact_clock_consumers(bad):
    easyfl.reset()
    with pytest.raises(ValueError, match="round_sync"):
        easyfl.init({"model": "linear", "dataset": "synthetic",
                     "tracking": {"round_sync": False}, **bad})
        easyfl.run()
    easyfl.reset()


# ---------------------------------------------------------------------------
# kill-and-resume bit-identity with fusion on (compressed EF state rides
# the same tiered store as the staged path)
# ---------------------------------------------------------------------------


def test_kill_and_resume_bit_identical_with_fusion(tmp_path):
    from repro.core.config import Config
    from repro.core.rounds import Trainer
    from repro.core.server import Server
    from repro.data.fed_data import build_federated_data
    from repro.models.registry import get_model

    def make(d):
        cfg = Config.make({
            "model": "linear",
            "data": {"dataset": "synthetic", "num_clients": 8,
                     "batch_size": 32},
            "server": {"rounds": 4, "clients_per_round": 4},
            "client": {"local_epochs": 1, "lr": 0.1, "compression": "stc"},
            "resources": {"execution": "batched", "round_fusion": "auto"},
            "checkpoint": {"every": 2, "dir": d},
            "tracking": {"enabled": False},
        })
        model = get_model(cfg.model)
        fed = build_federated_data(cfg.data)
        t = Trainer(cfg, model, fed, server=Server(model, cfg, fed.test))
        return t, model

    da, db = str(tmp_path / "A"), str(tmp_path / "B")
    ta, model = make(da)
    ta.server.params = model.init(jax.random.PRNGKey(ta.cfg.seed))
    ra = ta.run()

    tb, model = make(db)
    tb.server.params = model.init(jax.random.PRNGKey(tb.cfg.seed))
    for r in range(2):                          # ... killed after round 2
        tb.run_round(r)
        tb._maybe_checkpoint(r + 1)
    tc, _ = make(db)
    rc = tc.resume()

    _assert_params(ra, rc)
    assert len(rc["history"]) == 4


# ---------------------------------------------------------------------------
# 8-device mesh parity (subprocess owns the forced device count)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fused_matches_staged_on_8_device_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, numpy as np
        assert len(jax.devices()) == 8
        import repro as easyfl

        def run(fusion, comp):
            easyfl.reset()
            easyfl.init({
                "model": "linear", "dataset": "synthetic",
                "data": {"num_clients": 12, "batch_size": 32},
                "server": {"rounds": 2, "clients_per_round": 8},
                "client": {"local_epochs": 1, "lr": 0.1,
                           "compression": comp},
                "resources": {"execution": "batched",
                              "round_fusion": fusion,
                              "distributed": "data"},
            })
            res = easyfl.run()
            easyfl.reset()
            return res

        for comp in ("none", "stc"):
            f, s = run("auto", comp), run("off", comp)
            for x, y in zip(jax.tree_util.tree_leaves(f["params"]),
                            jax.tree_util.tree_leaves(s["params"])):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=0, atol=1e-6)
        print("MESH_FUSED_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_FUSED_OK" in r.stdout
