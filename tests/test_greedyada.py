"""Property tests for GreedyAda (paper Algorithm 1, Eq. 1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.sched.greedyada import (
    GreedyAda, random_allocation, slowest_allocation,
)


def _makespan(groups, times):
    return max((sum(times[c] for c in g) for g in groups if g), default=0.0)


@given(times=st.lists(st.floats(0.01, 100.0), min_size=4, max_size=60),
       m=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_lpt_greedy_bound(times, m):
    """List-scheduling guarantee: makespan <= sum/m + max_t [Graham 1969],
    and every client is placed exactly once."""
    ids = [f"c{i}" for i in range(len(times))]
    t = dict(zip(ids, times))
    sched = GreedyAda(num_devices=m)
    sched.update(t)                      # profile everything
    groups = sched.allocate(ids)
    ms = _makespan(groups, t)
    assert ms <= sum(times) / m + max(times) + 1e-6
    flat = [c for g in groups for c in g]
    assert sorted(flat) == sorted(ids)


@given(times=st.lists(st.floats(0.1, 50.0), min_size=8, max_size=40),
       m=st.integers(2, 6), seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_greedy_never_worse_than_slowest_first(times, m, seed):
    ids = [f"c{i}" for i in range(len(times))]
    t = dict(zip(ids, times))
    sched = GreedyAda(num_devices=m)
    sched.update(t)
    greedy = _makespan(sched.allocate(ids), t)
    slowest = _makespan(slowest_allocation(ids, m, t), t)
    assert greedy <= slowest + 1e-9


def test_adaptive_profiling_updates_default():
    """Algorithm 1 lines 26-27: t <- avg*m + t*(1-m)."""
    sched = GreedyAda(num_devices=2, default_time=1.0, momentum=0.5)
    sched.update({"a": 3.0, "b": 5.0})
    assert sched.default_time == pytest.approx(0.5 * 4.0 + 0.5 * 1.0)
    assert sched.profiles["a"].profiled
    # unprofiled clients estimated with the updated default
    assert sched._estimate("zzz") == pytest.approx(2.5)
    assert sched._estimate("a") == pytest.approx(3.0)


def test_unprofiled_clients_use_default_then_converge():
    sched = GreedyAda(num_devices=2, default_time=1.0, momentum=1.0)
    ids = [f"c{i}" for i in range(6)]
    true_times = {c: float(i + 1) for i, c in enumerate(ids)}
    # round 1: all defaults -> any allocation; then profile
    g1 = sched.allocate(ids)
    sched.update({c: true_times[c] for g in g1 for c in g})
    g2 = sched.allocate(ids)
    # with exact profiles, LPT on {1..6}/2 devices achieves the optimum (11)
    ms = _makespan(g2, true_times)
    assert ms == pytest.approx(11.0)


def test_random_allocation_covers_everyone():
    ids = [f"c{i}" for i in range(13)]
    groups = random_allocation(ids, 4, seed=3)
    flat = sorted(c for g in groups for c in g)
    assert flat == sorted(ids)
    assert len(groups) == 4
