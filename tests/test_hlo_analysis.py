"""HLO cost-model tests: known-FLOPs programs, scan trip counting,
collective detection (subprocess with forced multi-device host)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_matmul_flops_exact():
    s = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    s2 = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    hlo = _compile_text(lambda a, b: a @ b, s, s2)
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)


def test_scan_multiplies_flops_by_trip_count():
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, b):
        def body(x, _):
            return jnp.tanh(x @ b), ()
        out, _ = jax.lax.scan(body, a, None, length=12)
        return out

    hlo = _compile_text(f, s, s)
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(12 * 2 * 128**3, rel=0.05)
    assert 12 in c.while_trips.values()


def test_hbm_counts_matmul_traffic():
    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    hlo = _compile_text(lambda a, b: a @ b, s, s)
    c = analyze_hlo(hlo)
    # read a + read b + write out = 3 * 4MB (within 2x for copies)
    assert 0.5 * 12e6 <= c.hbm_bytes <= 2.5 * 12e6


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze_hlo

    from repro.models.sharding import make_mesh
    mesh = make_mesh((8,), ("d",))
    sh = NamedSharding(mesh, P("d", None))
    rep = NamedSharding(mesh, P())
    s = jax.ShapeDtypeStruct((1024, 256), jnp.float32)

    # data-parallel grad-like reduction -> all-reduce
    def f(x):
        return jnp.sum(x * x)
    hlo = jax.jit(f, in_shardings=(sh,)).lower(s).compile().as_text()
    c = analyze_hlo(hlo)
    out = {"allreduce_ops": c.collective_counts.get("all-reduce", 0),
           "coll_bytes": c.collective_bytes}
    print(json.dumps(out))
""")


def test_collectives_detected_under_mesh(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["allreduce_ops"] >= 1
    assert out["coll_bytes"] > 0
