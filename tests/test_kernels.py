"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
hypothesis shape/dtype sweeps as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# fedavg_agg
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 24), d=st.integers(1, 5000),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=12, deadline=None)
def test_fedavg_kernel_matches_ref(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    u = jax.random.normal(key, (n, d), dtype)
    w = jax.nn.softmax(jax.random.normal(key, (n,)))
    out = ops.fedavg_aggregate(u, w)
    exp = ref.fedavg_ref(u, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)


def test_fedavg_kernel_weighted_identity():
    u = jnp.stack([jnp.full((100,), 3.0), jnp.full((100,), 5.0)])
    out = ops.fedavg_aggregate(u, jnp.array([0.25, 0.75]))
    np.testing.assert_allclose(np.asarray(out), 4.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# stc_topk
# ---------------------------------------------------------------------------


@given(shape=st.sampled_from([(100,), (8, 1024), (3, 700), (33, 129), (9000,)]),
       keep=st.sampled_from([0.01, 0.05, 0.2]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=12, deadline=None)
def test_stc_kernel_matches_ref(shape, keep, dtype):
    x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31),
                          shape, dtype)
    out = ops.stc_compress(x, keep)
    exp = ref.stc_ref(x, keep)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=1e-3,
                               atol=1e-5)


def test_stc_semantics_sparsity_and_ternary():
    """Kept fraction ~ keep_frac; kept values are +-mu per tile."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 1024))
    out = np.asarray(ops.stc_compress(x, 0.05))
    frac = (out != 0).mean()
    assert 0.03 <= frac <= 0.08, frac
    tile = out.reshape(2, 8192)
    for t in tile:
        vals = np.unique(np.abs(t[t != 0]).round(6))
        assert len(vals) == 1         # single magnitude per tile (ternary)


def test_stc_keeps_largest_magnitudes():
    x = jnp.array(np.random.RandomState(0).randn(8 * 1024) * 0.1)
    x = x.at[:50].set(10.0)           # planted heavy entries
    out = np.asarray(ops.stc_compress(x, 50 / 8192))
    assert (out[:50] != 0).all()


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------


@given(shape=st.sampled_from([(64,), (8, 1024), (5, 333), (200, 77)]),
       scale=st.floats(0.01, 100.0))
@settings(max_examples=10, deadline=None)
def test_quant_roundtrip_error_bound(shape, scale):
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * scale
    q, s = ops.quantize(x)
    xd = ops.dequantize(q, s, x.shape)
    err = np.max(np.abs(np.asarray(xd) - np.asarray(x)))
    # per-tile scale: max error 0.5 * scale_tile <= 0.5 * max|x| / 127
    assert err <= 0.51 * float(jnp.max(jnp.abs(x))) / 127.0 + 1e-7


def test_quant_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 4000))
    q, s = ops.quantize(x)
    qr, sr = ref.quantize_ref(x)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)))) == 0
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = ops.dequantize(q, s, x.shape)
    xdr = ref.dequantize_ref(qr, sr, x.shape)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xdr), rtol=1e-6)


# ---------------------------------------------------------------------------
# rwkv6 wkv kernel
# ---------------------------------------------------------------------------


@given(b=st.integers(1, 3), t=st.sampled_from([64, 128, 192]),
       h=st.integers(1, 3), hd=st.sampled_from([8, 16, 32]))
@settings(max_examples=8, deadline=None)
def test_wkv6_kernel_matches_sequential(b, t, h, hd):
    keys = jax.random.split(jax.random.PRNGKey(b * 100 + t + h + hd), 5)
    r = jax.random.normal(keys[0], (b, t, h, hd)) * 0.5
    k = jax.random.normal(keys[1], (b, t, h, hd)) * 0.5
    v = jax.random.normal(keys[2], (b, t, h, hd)) * 0.5
    logw = -jnp.exp(jax.random.normal(keys[3], (b, t, h, hd)) * 0.5)
    u = jax.random.normal(keys[4], (h, hd)) * 0.3
    s0 = jnp.zeros((b, h, hd, hd))
    yk, sk = ops.wkv6(r, k, v, logw, u, s0)
    yr, sr_ = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr_),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_kernel_nonzero_initial_state():
    b, t, h, hd = 2, 64, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    r, k, v = (jax.random.normal(keys[i], (b, t, h, hd)) * 0.4
               for i in range(3))
    logw = -jnp.exp(jax.random.normal(keys[3], (b, t, h, hd)))
    u = jax.random.normal(keys[4], (h, hd)) * 0.2
    s0 = jax.random.normal(keys[5], (b, h, hd, hd)) * 0.5
    yk, sk = ops.wkv6(r, k, v, logw, u, s0)
    yr, sr_ = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_chunked_model_path_matches_sequential():
    """The model's chunked jnp path is itself validated against the
    sequential recurrence (strong decay stress: no overflow by design)."""
    b, t, h, hd = 1, 256, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    r, k, v = (jax.random.normal(keys[i], (b, t, h, hd)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(keys[3], (b, t, h, hd)) + 1.5)  # strong
    u = jax.random.normal(keys[4], (h, hd))
    s0 = jnp.zeros((b, h, hd, hd))
    yc, sc = ref.wkv6_chunked_ref(r, k, v, logw, u, s0)
    yr, sr_ = ref.wkv6_ref(r, k, v, logw, u, s0)
    assert not np.isnan(np.asarray(yc)).any()
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
