"""Equivalence wall for federated LoRA fine-tuning
(``client.finetune = "lora"`` — ``repro.models.lora``):

* exactness: rank-0 / no-target wrapping is *bit-identical* to the frozen
  base forward; the merged ``W + (alpha/r)·A@B`` forward matches the
  hand-computed adapter path at 1e-5; adapter init (B = 0) makes round 0
  start from the base model exactly;
* three-engine e2e parity: sequential vs batched vs degenerate-async
  LoRA cohorts agree at 1e-5 over 3 rounds, with the whole transformer
  cohort compiled ONCE (``cohort_trace_count``);
* STC/int8-compressed adapters keep error-feedback residual semantics
  (sequential per-client stage vs the in-program batched store);
* ``comm_up_bytes`` counts only the adapter payload — the full-delta /
  adapter byte ratio equals the parameter-count ratio
  (per target leaf: D / (rank · (d_in + d_out)));
* loud failures: bad finetune configs, no-match targets, checkpoint
  finetune-mode mismatch on resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro as easyfl
from repro.core.batched import cohort_trace_count
from repro.models.lora import (
    adapter_defs, adapter_param_count, base_param_count, lora_wrap,
    merge_lora, target_paths,
)
from repro.models.small import linear_model

RANK, ALPHA = 4, 16.0


def _tiny_lm():
    from repro.models.llm import tiny_lm
    return tiny_lm()


def _init_adapters(wrapped, seed=0):
    return wrapped.init(jax.random.PRNGKey(seed))


def _randomize_b(adapters, seed=1):
    """Nonzero B factors (init gives B = 0) so the delta is live."""
    leaves, treedef = jax.tree_util.tree_flatten(adapters)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape, l.dtype) * 0.1
                  for k, l in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# exactness at the module level
# ---------------------------------------------------------------------------


def test_rank0_bit_identical_to_base():
    model = linear_model()
    base = model.init(jax.random.PRNGKey(0))
    wrapped = lora_wrap(model, base, rank=0)
    assert wrapped.defs == {}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    np.testing.assert_array_equal(np.asarray(wrapped.apply({}, x)),
                                  np.asarray(model.apply(base, x)))


def test_no_matching_target_bit_identical_to_base():
    model = linear_model()
    base = model.init(jax.random.PRNGKey(0))
    wrapped = lora_wrap(model, base, rank=RANK, targets=("no_such_leaf",))
    assert wrapped.defs == {}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    np.testing.assert_array_equal(np.asarray(wrapped.apply({}, x)),
                                  np.asarray(model.apply(base, x)))


def test_adapter_init_starts_from_base_exactly():
    """B = 0 at init => the adapter forward IS the base forward, bitwise
    (merge adds W + scale·A@0 in f32 and casts back)."""
    for model, x in [
        (linear_model(),
         jax.random.normal(jax.random.PRNGKey(1), (8, 64))),
        (_tiny_lm(),
         jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)),
    ]:
        base = model.init(jax.random.PRNGKey(0))
        wrapped = lora_wrap(model, base, rank=RANK, alpha=ALPHA)
        adapters = _init_adapters(wrapped)
        b_leaves = [np.asarray(ab["b"]) for ab in
                    jax.tree_util.tree_leaves(
                        adapters, is_leaf=lambda t: isinstance(t, dict)
                        and "b" in t)]
        assert b_leaves and all((b == 0).all() for b in b_leaves)
        np.testing.assert_array_equal(
            np.asarray(wrapped.apply(adapters, x)),
            np.asarray(model.apply(base, x)))


def test_merged_forward_matches_hand_computed_adapter_path():
    """linear model: x@(W + (alpha/r)·A@B) + b == x@W + b + s·(x@A)@B."""
    model = linear_model()
    base = model.init(jax.random.PRNGKey(0))
    wrapped = lora_wrap(model, base, rank=RANK, alpha=ALPHA)
    adapters = _randomize_b(_init_adapters(wrapped))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    got = wrapped.apply(adapters, x)
    a, b = adapters["fc/w"]["a"], adapters["fc/w"]["b"]
    scale = ALPHA / RANK
    exp = (x @ base["fc"]["w"] + base["fc"]["b"]
           + scale * (x @ a) @ b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_merge_lora_matches_wrapper_on_transformer():
    """Explicitly merging into the base tree then running the base
    forward == the wrapper's merge-on-the-fly forward."""
    model = _tiny_lm()
    base = model.init(jax.random.PRNGKey(0))
    wrapped = lora_wrap(model, base, rank=RANK, alpha=ALPHA)
    adapters = _randomize_b(_init_adapters(wrapped))
    x = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, 64)
    merged = merge_lora(base, adapters, ALPHA / RANK)
    np.testing.assert_allclose(np.asarray(model.apply(merged, x)),
                               np.asarray(wrapped.apply(adapters, x)),
                               rtol=1e-5, atol=1e-5)


def test_target_patterns_select_subtrees():
    model = _tiny_lm()
    all_paths = target_paths(model.defs)
    attn_paths = target_paths(model.defs, ("attn",))
    assert attn_paths and set(attn_paths) < set(all_paths)
    assert all("attn" in p for p in attn_paths)
    # 1-dim leaves (norm scales) are never eligible
    assert not any("norm" in p for p in all_paths)
    defs = adapter_defs(model.defs, RANK, ("attn",))
    assert set(defs) == set(attn_paths)


def test_stacked_segments_get_batched_adapters():
    """Scan-stacked transformer segments carry the leading layers axis
    into their A/B factors."""
    model = _tiny_lm()
    defs = adapter_defs(model.defs, RANK)
    wq = defs["segments/0/attn/wq"]
    n_layers = model.defs["segments"][0]["attn"]["wq"].shape[0]
    assert wq["a"].shape[0] == n_layers and wq["a"].axes[0] == "layers"
    assert wq["b"].shape[:2] == (n_layers, RANK)


def test_adapter_param_count_formula():
    model = _tiny_lm()
    count = adapter_param_count(model, RANK)
    expect = sum(
        int(np.prod(d["a"].shape)) + int(np.prod(d["b"].shape))
        for d in adapter_defs(model.defs, RANK).values())
    assert count == expect > 0
    assert count < base_param_count(model)


# ---------------------------------------------------------------------------
# config validation + api folding (loud failures)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad, match", [
    ({"finetune": "qlora"}, "finetune"),
    ({"finetune": "lora", "lora_rank": 0}, "lora_rank"),
    ({"finetune": "lora", "lora_rank": -3}, "lora_rank"),
    ({"finetune": "lora", "lora_alpha": 0.0}, "lora_alpha"),
    ({"finetune": "lora", "lora_alpha": float("nan")}, "lora_alpha"),
    ({"finetune": "lora", "lora_targets": ("ok", "")}, "lora_targets"),
])
def test_invalid_finetune_config_rejected(bad, match):
    import dataclasses

    from repro.core.config import ClientConfig, validate_finetune_config
    cfg = dataclasses.replace(ClientConfig(), **bad)
    with pytest.raises((ValueError, TypeError), match=match):
        validate_finetune_config(cfg)


def test_api_folds_flat_finetune_keys():
    easyfl.reset()
    cfg = easyfl.init({"model": "linear", "dataset": "synthetic",
                       "finetune": "lora", "lora_rank": 2,
                       "lora_alpha": 8.0})
    easyfl.reset()
    assert cfg.client.finetune == "lora"
    assert cfg.client.lora_rank == 2 and cfg.client.lora_alpha == 8.0


def test_trainer_rejects_no_match_targets():
    easyfl.reset()
    easyfl.init({"model": "linear", "dataset": "synthetic",
                 "finetune": "lora", "lora_rank": 2,
                 "lora_targets": ("no_such_leaf",)})
    with pytest.raises(ValueError, match="matched no eligible"):
        easyfl.run()
    easyfl.reset()


# ---------------------------------------------------------------------------
# three-engine e2e parity + single-program contract
# ---------------------------------------------------------------------------


def _run(resources, client_over=None, server_over=None, data_over=None,
         model_dataset=("tiny_lm", "tiny_lm")):
    model, dataset = model_dataset
    easyfl.reset()
    easyfl.init({
        "model": model, "dataset": dataset,
        "data": {"num_clients": 8, "batch_size": 32, **(data_over or {})},
        "server": {"rounds": 3, "clients_per_round": 4,
                   **(server_over or {})},
        "client": {"local_epochs": 1, "lr": 0.1, "finetune": "lora",
                   "lora_rank": RANK, "lora_alpha": ALPHA,
                   **(client_over or {})},
        "resources": resources,
    })
    t0 = cohort_trace_count()
    res = easyfl.run()
    res["traces"] = cohort_trace_count() - t0
    easyfl.reset()
    return res


def _assert_equivalent(ra, rb, bytes_exact=True):
    for a, b in zip(jax.tree_util.tree_leaves(ra["params"]),
                    jax.tree_util.tree_leaves(rb["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        [h["train_loss"] for h in ra["history"]],
        [h["train_loss"] for h in rb["history"]], rtol=1e-4)
    if bytes_exact:
        assert ([h["comm_up_bytes"] for h in ra["history"]]
                == [h["comm_up_bytes"] for h in rb["history"]])


def test_three_engine_lora_parity_zero_retraces():
    """sequential vs batched vs degenerate-async (wave = cohort,
    staleness 0) LoRA transformer cohorts: one trajectory, and each
    compiled engine traces its cohort program exactly once for all 3
    rounds."""
    rs = _run({"execution": "sequential"})
    rb = _run({"execution": "batched"})
    ra = _run({"execution": "async", "buffer_size": 4,
               "max_concurrency": 4})
    _assert_equivalent(rs, rb)
    _assert_equivalent(rb, ra)
    assert rb["traces"] == 1, "batched LoRA cohort retraced"
    assert ra["traces"] == 1, "async LoRA waves retraced"


def test_transformer_lora_cohort_n20_single_program():
    """Acceptance: a transformer LoRA cohort of N >= 20 runs as ONE jitted
    program — 1 trace, 0 retraces across 3 rounds."""
    r = _run({"execution": "batched"},
             server_over={"clients_per_round": 20},
             data_over={"num_clients": 20})
    assert r["traces"] == 1
    assert all(h["clients"] == 20 for h in r["history"])


# ---------------------------------------------------------------------------
# compressed adapters: EF-residual semantics on the fast path
# ---------------------------------------------------------------------------


def test_stc_compressed_adapters_keep_ef_semantics():
    """3 rounds of STC-compressed adapter uploads: the batched in-program
    residual store must match the sequential per-client EF stage —
    trajectory AND nnz-derived wire bytes."""
    over = {"compression": "stc", "stc_sparsity": 0.25}
    _assert_equivalent(_run({"execution": "sequential"}, over),
                       _run({"execution": "batched"}, over))


def test_int8_compressed_adapters_match_sequential():
    over = {"compression": "int8"}
    _assert_equivalent(
        _run({"execution": "sequential"}, over,
             model_dataset=("linear", "synthetic")),
        _run({"execution": "batched"}, over,
             model_dataset=("linear", "synthetic")))


# ---------------------------------------------------------------------------
# wire accounting: only adapters ever hit the wire
# ---------------------------------------------------------------------------


def test_comm_bytes_count_only_adapter_payload():
    model = _tiny_lm()
    full = _run({"execution": "batched"}, {"finetune": "full"})
    lora = _run({"execution": "batched"})
    n_adapter = adapter_param_count(model, RANK)
    n_base = base_param_count(model)
    for h in lora["history"]:
        assert h["comm_up_bytes"] == n_adapter * 4 * h["clients"]
    for h in full["history"]:
        assert h["comm_up_bytes"] == n_base * 4 * h["clients"]
    # the full-delta / adapter ratio is the parameter-count ratio —
    # per target leaf, D / (rank · (d_in + d_out))
    ratio = (full["history"][0]["comm_up_bytes"]
             / lora["history"][0]["comm_up_bytes"])
    assert ratio == pytest.approx(n_base / n_adapter)
    assert ratio > 2.0


# ---------------------------------------------------------------------------
# checkpointing: adapters only, mode mismatch is loud
# ---------------------------------------------------------------------------


def test_checkpoint_resume_rejects_finetune_mismatch(tmp_path):
    from repro.core.config import Config
    from repro.core.rounds import Trainer
    from repro.data.fed_data import build_federated_data
    from repro.models.registry import get_model

    def make(client_over):
        cfg = Config.make({
            "model": "linear",
            "data": {"dataset": "synthetic", "num_clients": 8,
                     "batch_size": 32},
            "server": {"rounds": 2, "clients_per_round": 4},
            "client": {"local_epochs": 1, "lr": 0.1, **client_over},
            "checkpoint": {"dir": str(tmp_path), "every": 1},
            "tracking": {"enabled": False},
        })
        return Trainer(cfg, get_model("linear"),
                       build_federated_data(cfg.data))

    lora_trainer = make({"finetune": "lora", "lora_rank": 2})
    lora_trainer.run()
    # the checkpointed tree is adapters only — resuming as full must fail
    with pytest.raises(ValueError, match="finetune"):
        make({}).resume()
