"""O(num_leaves) message-size estimator vs the serializing oracle, and the
copy-free ndarray decode path."""
import numpy as np
import jax.numpy as jnp

from repro.comm import serialize


def _trees():
    rng = np.random.RandomState(0)
    yield {"w": np.zeros(10, np.float32)}
    yield {"w": rng.randn(300, 17).astype(np.float64),
           "b": np.arange(5, dtype=np.int8)}
    yield {"params": {"w": rng.randn(3, 4).astype(np.float32),
                      "b": np.zeros(4, np.float32)},
           "meta": {"round": 3, "lr": 0.1, "name": "client_0001",
                    "tags": ["a", "b"], "tuple": (1, 2.5, "x")},
           "flag": True, "none": None}
    yield [np.ones((64, 64), np.float32), {"nested": (np.int32(7),)}]
    yield {"bf16": jnp.ones((8, 8), jnp.bfloat16) * 2}
    yield {"big": np.zeros(100_000, np.float32)}     # bin32 header regime
    yield {"scalar": np.float32(1.5), "neg": -7, "large": 2**40}
    yield {1: "a", 300: [2.5], -7: None}             # non-str map keys


def test_estimator_matches_dumps_exactly():
    for tree in _trees():
        est = serialize.estimate_message_bytes(tree)
        exact = serialize.message_bytes(tree)
        assert est == exact, (est, exact, tree)


def test_estimator_does_not_serialize_scaling():
    """Estimator output is dominated by nbytes, not by walking data."""
    small = serialize.estimate_message_bytes({"w": np.zeros(10, np.float32)})
    large = serialize.estimate_message_bytes({"w": np.zeros(1000, np.float32)})
    assert large > small
    assert large >= 4000


def test_array_nbytes():
    assert serialize.array_nbytes(np.zeros((3, 4), np.float32)) == 48
    assert serialize.array_nbytes(jnp.zeros((2, 2), jnp.bfloat16)) == 8


def test_decode_returns_writable_no_copy():
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    out = serialize.loads(serialize.dumps({"w": arr}))["w"]
    np.testing.assert_array_equal(out, arr)
    assert out.flags.writeable                # bytearray-backed, no .copy()
    out[0, 0] = 99.0                          # mutation must not raise
    assert out[0, 0] == 99.0


def test_roundtrip_preserves_dtype_and_shape():
    for dt in (np.float32, np.float64, np.int32, np.int8, np.uint8, np.bool_):
        arr = (np.arange(24) % 2).astype(dt).reshape(2, 3, 4)
        out = serialize.loads(serialize.dumps(arr))
        np.testing.assert_array_equal(out, arr)
        assert out.dtype == arr.dtype
