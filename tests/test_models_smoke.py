"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED variant (<=2 layers, d_model<=256, <=4 experts),
runs one forward + one train step + one decode step on CPU, asserting output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.models.model import (
    Model, init_train_state, make_serve_step, make_train_step,
)
from repro.optim import sgd

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    cfg = get_arch(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    B, S = 2, 64
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": toks}
    frames = None
    if cfg.family in ("vlm", "audio"):
        frames = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        batch["frames"] = frames

    # forward: output shape + finite
    logits, aux = model.forward(model.init(key), toks, frames=frames)
    S_out = S + (cfg.n_frames if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    # one train step: loss finite, params update
    opt = sgd(0.05, momentum=0.9)
    state = init_train_state(model, opt, key)
    step = jax.jit(make_train_step(model, opt))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    changed = jax.tree_util.tree_map(
        lambda a, b: bool((a != b).any()), state.params, new_state.params)
    assert any(jax.tree_util.tree_leaves(changed))

    # one decode step against a small cache
    cache = model.init_cache(B, 32)
    serve = jax.jit(make_serve_step(model))
    lg, cache2 = serve(new_state.params, cache, toks[:, :1],
                       jnp.asarray(3, jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", ["glm4-9b", "recurrentgemma-9b",
                                  "rwkv6-1.6b"])
def test_arch_smoke_ring_decode(arch):
    """Sliding-window / recurrent decode (the long_500k path)."""
    cfg = get_arch(arch, reduced=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    cache = model.init_cache(1, 2048, ring=True)
    serve = jax.jit(make_serve_step(model, ring=True))
    tok = jnp.zeros((1, 1), jnp.int32)
    # position far beyond the ring window
    lg, cache = serve(params, cache, tok, jnp.asarray(2000, jnp.int32))
    assert bool(jnp.isfinite(lg).all())


def test_exact_assigned_hyperparameters():
    """Full configs carry the exact assigned numbers."""
    c = get_arch("internlm2-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 6144, 48, 8, 16384, 92544)
    c = get_arch("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (96, 18432, 96, 8, 73728, 256000)
    assert c.act == "sq_relu"
    c = get_arch("qwen3-moe-30b-a3b")
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_expert) == (128, 8, 768)
    c = get_arch("deepseek-v2-lite-16b")
    assert (c.mla.kv_lora_rank, c.moe.n_experts, c.moe.top_k,
            c.moe.n_shared) == (512, 64, 6, 2)
    c = get_arch("recurrentgemma-9b")
    assert c.block_pattern == ("rglru", "rglru", "local_attn")
    assert c.window == 2048
    c = get_arch("whisper-small")
    assert (c.encoder_layers, c.n_layers, c.d_model, c.n_frames) == \
        (12, 12, 768, 1500)
    assert not c.supports_long_context   # long_500k skip (DESIGN.md §4)


def test_param_counts_match_published_sizes():
    expected = {
        "rwkv6-1.6b": 1.6, "internlm2-20b": 20, "paligemma-3b": 2.6,
        "glm4-9b": 9.4, "phi3-medium-14b": 14, "nemotron-4-340b": 340,
        "qwen3-moe-30b-a3b": 30.5, "recurrentgemma-9b": 9.0,
        "deepseek-v2-lite-16b": 15.7,
    }
    for arch, billions in expected.items():
        n = get_arch(arch).param_count() / 1e9
        assert abs(n - billions) / billions < 0.25, (arch, n)
