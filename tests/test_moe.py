"""MoE dispatch correctness: the sort-based capacity dispatch must equal a
dense per-expert reference when capacity is not binding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ArchConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.models.layers import init_params
from repro.models.mlp import GATED_ACTS, _act


def _cfg(n_experts=4, top_k=2, n_shared=0, act="swiglu"):
    return ArchConfig(
        name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=64, act=act,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, n_shared=n_shared,
                      d_expert=64))


def _dense_reference(cfg, p, x):
    """Compute every expert densely, combine by renormalized top-k."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    top_w, top_ids, probs = moe_mod._router(cfg, p, xf)
    outs = []
    for e in range(m.n_experts):
        up = xf @ p["w_up"][e]
        gate = xf @ p["w_gate"][e] if cfg.act in GATED_ACTS else None
        h = _act(cfg.act, gate, up)
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, axis=1)         # (T, E, d)
    y = jnp.zeros((T, d))
    for slot in range(m.top_k):
        w = top_w[:, slot][:, None]
        y = y + w * jnp.take_along_axis(
            outs, top_ids[:, slot][:, None, None], axis=1)[:, 0]
    if m.n_shared:
        from repro.models.mlp import mlp
        y = y + mlp(cfg, p["shared"], x).reshape(T, d)
    return y.reshape(B, S, d)


@pytest.mark.parametrize("act,n_shared", [("swiglu", 0), ("gelu", 0),
                                          ("swiglu", 1)])
def test_moe_matches_dense_reference(act, n_shared):
    cfg = _cfg(act=act, n_shared=n_shared)
    p = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    # generous capacity: nothing dropped
    out, aux = moe_mod.moe_ffn(cfg, p, x, capacity_factor=8.0)
    exp = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-4)


def test_moe_capacity_drops_overflow_tokens():
    """With capacity_factor << 1 most tokens are dropped -> output norm
    shrinks but stays finite (residual passes through in the layer)."""
    cfg = _cfg()
    p = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    full, _ = moe_mod.moe_ffn(cfg, p, x, capacity_factor=8.0)
    tight, _ = moe_mod.moe_ffn(cfg, p, x, capacity_factor=0.25)
    assert bool(jnp.isfinite(tight).all())
    assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(full))


def test_router_aux_loss_uniform_when_balanced():
    """Perfectly uniform routing gives aux loss ~= 1 (Switch normalization)."""
    cfg = _cfg(n_experts=8, top_k=2)
    T, E = 4096, 8
    probs = jnp.full((T, E), 1.0 / E)
    ids = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], axis=1)
    aux = moe_mod.load_balance_loss(cfg, probs, ids)
    assert float(aux) == pytest.approx(1.0, rel=1e-3)


def test_router_aux_loss_penalizes_collapse():
    cfg = _cfg(n_experts=8, top_k=1)
    T, E = 1024, 8
    probs = jnp.zeros((T, E)).at[:, 0].set(1.0)
    ids = jnp.zeros((T, 1), jnp.int32)
    aux = moe_mod.load_balance_loss(cfg, probs, ids)
    assert float(aux) == pytest.approx(8.0, rel=1e-3)   # E * 1 * 1


def test_moe_gradients_flow_to_experts_and_router():
    cfg = _cfg()
    p = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        out, aux = moe_mod.moe_ffn(cfg, p, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_up"]).max()) > 0
