"""Power-of-Choice (selection stage) and FedBuff (aggregation stage)
plugins — each changes exactly one stage and still trains (Table VII)."""
import numpy as np
import pytest

import repro as easyfl
from repro.core.strategies import FedBuffServer, PowerOfChoiceServer


@pytest.fixture(autouse=True)
def _reset():
    easyfl.reset()
    yield
    easyfl.reset()


CFG = {
    "model": "linear", "dataset": "synthetic",
    "data": {"num_clients": 15, "partition": "dir", "batch_size": 32},
    "server": {"rounds": 5, "clients_per_round": 5},
    "client": {"local_epochs": 2, "lr": 0.1},
}


def test_power_of_choice_trains_and_biases_selection():
    easyfl.init(CFG)
    easyfl.register_server(PowerOfChoiceServer)
    res = easyfl.run()
    accs = [h["accuracy"] for h in res["history"]]
    assert accs[-1] > accs[0]
    # after warmup, selection must be loss-ranked, not uniform:
    # server keeps per-client losses
    from repro.core import api
    srv = api._ctx.trainer.server
    assert len(srv._last_loss) >= 5
    sel = srv.selection(sorted(srv._last_loss), round_id=99)
    losses = [srv._last_loss[c] for c in sel]
    # selected clients' losses are the largest among a candidate set
    assert np.mean(losses) >= np.mean(list(srv._last_loss.values())) - 1e-6


def test_fedbuff_trains_with_staleness_weighting():
    easyfl.init({**CFG, "system_heterogeneity": {"enabled": True}})
    easyfl.register_server(FedBuffServer)
    res = easyfl.run()
    accs = [h["accuracy"] for h in res["history"]]
    assert accs[-1] > accs[0]
    assert accs[-1] > 0.5
