"""Pure-JAX optimizers vs hand-computed updates, and the traced-hyperparam
variants (per-client vectorization) vs their closure twins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWHParams, SGDHParams, adamw, adamw_traced, apply_updates,
    clip_by_global_norm, global_norm, sgd, sgd_traced,
)


def test_sgd_plain():
    opt = sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -1.0])}
    upd, state = opt.update(grads, opt.init(params), params)
    out = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.95, 2.1])


def test_sgd_momentum_two_steps():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([1.0])}
    state = opt.init(params)
    upd, state = opt.update(g, state, params)
    params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), [-0.1])
    upd, state = opt.update(g, state, params)
    params = apply_updates(params, upd)
    # m2 = 0.9*1 + 1 = 1.9 -> w = -0.1 - 0.19
    np.testing.assert_allclose(np.asarray(params["w"]), [-0.29], rtol=1e-6)


def test_sgd_weight_decay():
    opt = sgd(0.1, weight_decay=0.5)
    params = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    upd, _ = opt.update(g, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1])  # -lr*wd*w


def test_adamw_first_step_is_lr_sized():
    opt = adamw(1e-3)
    params = {"w": jnp.array([1.0, -1.0])}
    g = {"w": jnp.array([0.3, -0.7])}
    upd, state = opt.update(g, opt.init(params), params)
    # bias-corrected first step = -lr * sign-ish(g)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               [-1e-3, 1e-3], rtol=1e-4)
    assert int(state.count) == 1


def test_adamw_converges_on_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"][0])) < 1e-2


@pytest.mark.parametrize("momentum,weight_decay,nesterov", [
    (0.0, 0.0, False),
    (0.9, 0.0, False),
    (0.9, 0.01, False),
    (0.9, 0.0, True),
    (0.5, 0.001, True),
])
def test_sgd_traced_matches_closure(momentum, weight_decay, nesterov):
    """The traced variant runs the same op sequence as the closure sgd, so
    multi-step trajectories agree bit-for-bit."""
    lr = 0.1
    closure = sgd(lr, momentum=momentum, weight_decay=weight_decay,
                  nesterov=nesterov)
    traced = sgd_traced(use_momentum=momentum != 0.0,
                        use_nesterov=nesterov)
    hp = SGDHParams(lr=jnp.float32(lr), momentum=jnp.float32(momentum),
                    weight_decay=jnp.float32(weight_decay),
                    nesterov=jnp.float32(1.0 if nesterov else 0.0))
    params_c = {"w": jnp.array([1.0, -2.0, 0.5])}
    params_t = {"w": jnp.array([1.0, -2.0, 0.5])}
    state_c = closure.init(params_c)
    state_t = traced.init(params_t, hp)
    key = jax.random.PRNGKey(0)
    for _ in range(5):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (3,))}
        up_c, state_c = closure.update(g, state_c, params_c)
        up_t, state_t = traced.update(g, state_t, params_t, hp)
        params_c = apply_updates(params_c, up_c)
        params_t = apply_updates(params_t, up_t)
        np.testing.assert_array_equal(np.asarray(params_c["w"]),
                                      np.asarray(params_t["w"]))


@pytest.mark.parametrize("b1,b2,eps,weight_decay", [
    (0.9, 0.999, 1e-8, 0.0),
    (0.8, 0.99, 1e-6, 0.01),
])
def test_adamw_traced_matches_closure(b1, b2, eps, weight_decay):
    lr = 0.01
    closure = adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    traced = adamw_traced()
    hp = AdamWHParams(lr=jnp.float32(lr), b1=jnp.float32(b1),
                      b2=jnp.float32(b2), eps=jnp.float32(eps),
                      weight_decay=jnp.float32(weight_decay))
    params_c = {"w": jnp.array([1.0, -2.0, 0.5])}
    params_t = {"w": jnp.array([1.0, -2.0, 0.5])}
    state_c = closure.init(params_c)
    state_t = traced.init(params_t, hp)
    key = jax.random.PRNGKey(1)
    for _ in range(5):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (3,))}
        up_c, state_c = closure.update(g, state_c, params_c)
        up_t, state_t = traced.update(g, state_t, params_t, hp)
        params_c = apply_updates(params_c, up_c)
        params_t = apply_updates(params_t, up_t)
        np.testing.assert_allclose(np.asarray(params_c["w"]),
                                   np.asarray(params_t["w"]),
                                   rtol=1e-6, atol=1e-7)


def test_sgd_traced_vmaps_heterogeneous_cohort():
    """One vmapped update with (N,) hyperparam vectors == N separate
    closure optimizers."""
    hps = [(0.1, 0.9, 0.0, 0.0), (0.02, 0.0, 0.01, 0.0),
           (0.3, 0.5, 0.0, 1.0)]
    traced = sgd_traced(use_momentum=True, use_nesterov=True)
    hp_vec = SGDHParams(*(jnp.asarray([h[i] for h in hps], jnp.float32)
                          for i in range(4)))
    params = jnp.stack([jnp.array([1.0, -1.0])] * 3)
    grads = jnp.asarray([[0.5, -1.0], [1.0, 2.0], [-0.3, 0.1]])
    state = jnp.zeros_like(params) + 0.2      # nonzero momentum buffer
    upd, _ = jax.vmap(traced.update)(grads, state, params, hp_vec)
    for i, (lr, m, wd, nest) in enumerate(hps):
        closure = sgd(lr, momentum=m, weight_decay=wd, nesterov=bool(nest))
        up_c, _ = closure.update(grads[i], state[i], params[i])
        np.testing.assert_allclose(np.asarray(upd[i]), np.asarray(up_c),
                                   rtol=1e-6, atol=1e-7)


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    unclipped, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0])
