"""Pure-JAX optimizers vs hand-computed updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, apply_updates, clip_by_global_norm, global_norm, sgd


def test_sgd_plain():
    opt = sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -1.0])}
    upd, state = opt.update(grads, opt.init(params), params)
    out = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.95, 2.1])


def test_sgd_momentum_two_steps():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([1.0])}
    state = opt.init(params)
    upd, state = opt.update(g, state, params)
    params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), [-0.1])
    upd, state = opt.update(g, state, params)
    params = apply_updates(params, upd)
    # m2 = 0.9*1 + 1 = 1.9 -> w = -0.1 - 0.19
    np.testing.assert_allclose(np.asarray(params["w"]), [-0.29], rtol=1e-6)


def test_sgd_weight_decay():
    opt = sgd(0.1, weight_decay=0.5)
    params = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    upd, _ = opt.update(g, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1])  # -lr*wd*w


def test_adamw_first_step_is_lr_sized():
    opt = adamw(1e-3)
    params = {"w": jnp.array([1.0, -1.0])}
    g = {"w": jnp.array([0.3, -0.7])}
    upd, state = opt.update(g, opt.init(params), params)
    # bias-corrected first step = -lr * sign-ish(g)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               [-1e-3, 1e-3], rtol=1e-4)
    assert int(state.count) == 1


def test_adamw_converges_on_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"][0])) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    unclipped, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0])
