"""Per-client optimizer heterogeneity: config knobs, sampling, validation.

* ``system_heterogeneity.hyperparam_choices`` samples per-client optimizer
  hyperparameters deterministically and the batched engine still matches
  sequential execution;
* invalid knob values (unknown fields, ``optimizer``, empty/NaN/negative
  choices) raise loudly at init;
* negative/NaN per-client hyperparameters are rejected at ``Client``
  construction, naming the client;
* lr-only heterogeneous cohorts keep the lean momentum-free program.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro as easyfl
from repro.core.config import (
    ClientConfig, SystemHeterogeneityConfig, validate_hyperparam_choices,
)
from repro.simulation.heterogeneity import SystemHeterogeneity


# ---------------------------------------------------------------------------
# sampling knob end-to-end
# ---------------------------------------------------------------------------


def _run(execution, het=None, rounds=3):
    easyfl.reset()
    easyfl.init({
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 12, "batch_size": 32},
        "server": {"rounds": rounds, "clients_per_round": 5},
        "client": {"local_epochs": 2, "lr": 0.1},
        "system_heterogeneity": het or {},
        "resources": {"execution": execution},
    })
    res = easyfl.run()
    easyfl.reset()
    return res


def _assert_equivalent(rs, rb):
    for a, b in zip(jax.tree_util.tree_leaves(rs["params"]),
                    jax.tree_util.tree_leaves(rb["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        [h["train_loss"] for h in rs["history"]],
        [h["train_loss"] for h in rb["history"]], rtol=1e-4)


def test_sampled_hyperparams_batched_equals_sequential():
    """The low-code path: momentum/wd/nesterov sampled per client via the
    heterogeneity config; batched and sequential engines must agree."""
    het = {"hyperparam_choices": {"momentum": (0.0, 0.5, 0.9),
                                  "weight_decay": (0.0, 0.01),
                                  "nesterov": (False, True)}}
    _assert_equivalent(_run("sequential", het), _run("batched", het))


def test_sampled_mu_and_clip_compose_with_hyperparams():
    """FedProx per-client mu and grad clipping ride the same CohortVectors
    as the optimizer hyperparams — all sampled, still equivalent."""
    het = {"hyperparam_choices": {"momentum": (0.0, 0.9),
                                  "proximal_mu": (0.0, 0.01, 0.1),
                                  "max_grad_norm": (0.0, 1.0)}}
    _assert_equivalent(_run("sequential", het), _run("batched", het))


def test_sampling_is_deterministic_per_client():
    cfg = SystemHeterogeneityConfig(
        hyperparam_choices={"momentum": (0.0, 0.5, 0.9),
                            "lr": (0.01, 0.1)})
    a = SystemHeterogeneity(cfg)
    b = SystemHeterogeneity(cfg)
    ids = [f"client_{i:04d}" for i in range(50)]
    for cid in ids:
        assert a.hyperparam_overrides(cid) == b.hyperparam_overrides(cid)
    sampled = {tuple(a.hyperparam_overrides(c).items()) for c in ids}
    assert len(sampled) > 1          # actually heterogeneous
    # different seed -> different assignment somewhere
    c = SystemHeterogeneity(dataclasses.replace(cfg, seed=7))
    assert any(a.hyperparam_overrides(i) != c.hyperparam_overrides(i)
               for i in ids)


def test_sampling_preserves_python_types():
    het = SystemHeterogeneity(SystemHeterogeneityConfig(
        hyperparam_choices={"nesterov": (False, True)}))
    v = het.hyperparam_overrides("x")["nesterov"]
    assert isinstance(v, bool)


def test_sampling_independent_of_speed_enabled_flag():
    """hyperparam_choices works without enabled=True (which gates only the
    virtual-clock speed simulation)."""
    het = SystemHeterogeneity(SystemHeterogeneityConfig(
        enabled=False, hyperparam_choices={"momentum": (0.0, 0.9)}))
    assert het.hyperparam_overrides("c") != {}
    assert het.speed_ratio("c") == 1.0


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("choices,match", [
    ({"optimizer": ("sgd", "adamw")}, "not per-client sampleable"),
    ({"no_such_field": (1,)}, "not per-client sampleable"),
    ({"momentum": ()}, "non-empty"),
    ({"momentum": 0.9}, "non-empty|sequence"),
    ({"momentum": (0.5, 1.5)}, "invalid value"),
    ({"momentum": (float("nan"),)}, "invalid value"),
    ({"lr": (0.1, -0.1)}, "invalid value"),
    ({"adam_b1": (1.0,)}, "invalid value"),
    ({"adam_eps": (0.0,)}, "invalid value"),
    ({"weight_decay": (-1e-4,)}, "invalid value"),
    ("momentum", "mapping"),
])
def test_hyperparam_choices_validation_rejects(choices, match):
    with pytest.raises(ValueError, match=match):
        validate_hyperparam_choices(choices)


def test_bad_hyperparam_choices_raise_at_trainer_init():
    easyfl.reset()
    easyfl.init({
        "model": "linear", "dataset": "synthetic",
        "system_heterogeneity": {"hyperparam_choices": {"momentum": (2.0,)}},
    })
    with pytest.raises(ValueError, match="invalid value"):
        easyfl.run()
    easyfl.reset()


# ---------------------------------------------------------------------------
# per-client hyperparameter validation at Client construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("over", [
    {"lr": -0.1}, {"lr": float("nan")}, {"momentum": -0.5},
    {"momentum": 1.0}, {"weight_decay": -1.0}, {"adam_b1": float("nan")},
    {"adam_b2": 1.0}, {"adam_eps": -1e-8}, {"proximal_mu": -0.1},
    {"max_grad_norm": float("-inf")},
])
def test_client_rejects_invalid_hyperparams_naming_client(over):
    from repro.core.client import Client
    from repro.data.fed_data import ClientData
    from repro.models.small import linear_model

    rng = np.random.RandomState(0)
    data = ClientData(rng.randn(8, 64).astype(np.float32),
                      rng.randint(0, 10, 8).astype(np.int32))
    cfg = dataclasses.replace(ClientConfig(), **over)
    field = next(iter(over))
    with pytest.raises(ValueError, match=f"bad_client.*{field}"):
        Client("bad_client", linear_model(), data, cfg, batch_size=8)


# ---------------------------------------------------------------------------
# lr-only cohorts keep the lean (momentum-free where possible) fast path
# ---------------------------------------------------------------------------


def test_cohort_larger_than_optimizer_cache_still_vectorizes():
    """get_optimizer lru-caches 128 instances; a config-derived cohort
    with more distinct hyperparam combos than that must still be
    recognized as from-config (name equality, not object identity) and
    vectorize instead of being misdiagnosed as hand-assigned."""
    from repro.core.batched import BatchedExecutor
    from repro.core.client import Client
    from repro.data.fed_data import ClientData
    from repro.models.small import linear_model

    model = linear_model()
    rng = np.random.RandomState(0)
    data = ClientData(rng.randn(8, 64).astype(np.float32),
                      rng.randint(0, 10, 8).astype(np.int32))
    clients = [
        Client(f"c{i}", model, data,
               ClientConfig(local_epochs=1, lr=0.001 * (i + 1)),
               batch_size=8)
        for i in range(140)
    ]
    vec, opt = BatchedExecutor.cohort_vectors(clients, 256)
    np.testing.assert_allclose(vec.hp.lr[:140],
                               [0.001 * (i + 1) for i in range(140)],
                               rtol=1e-6)


def test_lr_only_cohort_skips_momentum_state():
    """A zero-momentum cohort heterogeneous only in lr must build the
    momentum-free traced SGD (empty opt-state), like the closure path."""
    from repro.core.batched import BatchedExecutor
    from repro.core.client import Client
    from repro.data.fed_data import ClientData
    from repro.models.small import linear_model

    model = linear_model()
    rng = np.random.RandomState(0)
    clients = []
    for i, lr in enumerate([0.1, 0.02, 0.3]):
        data = ClientData(rng.randn(32, 64).astype(np.float32),
                          rng.randint(0, 10, 32).astype(np.int32))
        cfg = ClientConfig(local_epochs=1, lr=lr, momentum=0.0)
        clients.append(Client(f"c{i}", model, data, cfg, batch_size=16))
    vec, opt = BatchedExecutor.cohort_vectors(clients, 4)
    assert "momentum=False" in opt.name
    assert opt.init({"w": np.zeros(2)}, vec.hp) == ()
    assert vec.hp.lr.shape == (4,)
    np.testing.assert_allclose(vec.hp.lr[:3], [0.1, 0.02, 0.3])
