"""Property tests for statistical-heterogeneity partitioners (paper §V-A)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.partition import class_partition, dirichlet_partition, iid_partition, partition, unbalanced_sizes


def _labels(n, k, seed):
    return np.random.RandomState(seed).randint(0, k, n)


@given(n=st.integers(200, 2000), n_clients=st.integers(2, 20),
       seed=st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_iid_partition_is_disjoint_cover(n, n_clients, seed):
    labels = _labels(n, 10, seed)
    parts = iid_partition(labels, n_clients, seed)
    assert len(parts) == n_clients
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@given(alpha=st.floats(0.05, 10.0), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_dirichlet_partition_disjoint(alpha, seed):
    labels = _labels(1000, 10, seed)
    parts = dirichlet_partition(labels, 8, alpha, seed)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(np.unique(allidx))
    assert len(allidx) <= 1000
    assert min(len(p) for p in parts) >= 2


def test_dirichlet_low_alpha_is_more_skewed():
    """Smaller alpha -> more non-IID (paper Table IV ordering)."""
    labels = _labels(20_000, 10, 0)

    def skew(alpha):
        parts = dirichlet_partition(labels, 10, alpha, 0)
        # average per-client entropy of the class distribution
        ents = []
        for p in parts:
            if len(p) == 0:
                continue
            hist = np.bincount(labels[p], minlength=10) / len(p)
            ents.append(-np.sum(hist * np.log(hist + 1e-12)))
        return np.mean(ents)

    assert skew(0.1) < skew(100.0)


@given(k=st.integers(1, 5), seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_class_partition_respects_class_budget(k, seed):
    labels = _labels(4000, 10, seed)
    parts = class_partition(labels, 10, k, seed)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(np.unique(allidx))
    n_classes = [len(np.unique(labels[p])) for p in parts if len(p)]
    # the greedy placer may exceed k only via leftover spill
    assert np.mean(n_classes) <= k + 1.0


@given(total=st.integers(100, 5000), n=st.integers(2, 30),
       sigma=st.floats(0.1, 2.0), seed=st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_unbalanced_sizes_sum_and_minimum(total, n, sigma, seed):
    sizes = unbalanced_sizes(total, n, sigma, seed)
    assert sizes.sum() == total
    assert (sizes >= 1).all()


def test_unbalanced_creates_spread():
    sizes = unbalanced_sizes(10_000, 20, sigma=1.0, seed=0)
    assert sizes.max() > 2 * sizes.min()


def test_partition_one_stop_all_methods():
    labels = _labels(2000, 10, 0)
    for method in ("iid", "dir", "class"):
        parts = partition(labels, 10, method=method, unbalanced=True, seed=1)
        assert len(parts) == 10
        allidx = np.concatenate([p for p in parts if len(p)])
        assert len(allidx) == len(np.unique(allidx))
