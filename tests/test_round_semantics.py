"""Round-level FedAvg semantics: sample-weighted reported loss and
FedBuff's cross-round buffer (staleness that actually ages)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro as easyfl
from repro.core.client import Client
from repro.core.config import Config
from repro.core.rounds import Trainer
from repro.core.server import Server
from repro.core.strategies.fedbuff import FedBuffServer
from repro.data.fed_data import build_federated_data
from repro.models.registry import get_model


def _zero_update(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)


def test_train_loss_weighted_by_num_samples(monkeypatch):
    """A 1-sample client with huge loss must barely move the reported
    cohort loss (FedAvg weighting), not dominate an unweighted mean."""
    cfg = Config.make({
        "model": "linear",
        "data": {"dataset": "synthetic", "num_clients": 2, "batch_size": 32},
        "server": {"rounds": 1, "clients_per_round": 2, "test_every": 0},
        "tracking": {"enabled": False},
    })
    model = get_model(cfg.model)
    fed = build_federated_data(cfg.data)
    trainer = Trainer(cfg, model, fed, server=Server(model, cfg, fed.test))
    trainer.server.params = model.init(jax.random.PRNGKey(0))
    params = trainer.server.params

    canned = {"client_0000": (1, 10.0), "client_0001": (1000, 1.0)}

    def fake_run_round(self, payload, round_id):
        n, loss = canned.get(self.client_id, (1, 0.0))
        return {"client_id": self.client_id, "update": _zero_update(params),
                "num_samples": n, "metrics": {"loss": loss, "accuracy": 0.0},
                "train_time": 0.01}

    monkeypatch.setattr(Client, "run_round", fake_run_round)
    ids = sorted(fed.client_ids)[:2]
    monkeypatch.setattr(trainer.server, "selection",
                        lambda client_ids, round_id: list(ids))
    metrics = trainer.run_round(0)
    expected = (1 * 10.0 + 1000 * 1.0) / 1001
    assert metrics["train_loss"] == pytest.approx(expected, rel=1e-6)
    assert abs(metrics["train_loss"] - 5.5) > 1       # not the unweighted mean


# ---------------------------------------------------------------------------
# FedBuff cross-round buffering
# ---------------------------------------------------------------------------


def _mk_fedbuff():
    cfg = Config.make({
        "model": "linear",
        "data": {"dataset": "synthetic", "num_clients": 4, "batch_size": 32},
        "tracking": {"enabled": False},
    })
    model = get_model(cfg.model)
    fed = build_federated_data(cfg.data)
    srv = FedBuffServer(model, cfg, fed.test)
    srv.params = model.init(jax.random.PRNGKey(0))
    return srv


def _results(k, params, t0=0.0):
    return [{"update": _zero_update(params), "num_samples": 10,
             "train_time": t0 + i * 0.1} for i in range(k)]


def test_fedbuff_buffer_spans_rounds_and_staleness_ages(monkeypatch):
    """K=5 fed 3 updates/round: round 1 defers entirely, round 2 applies
    one batch of 5 and carries 1 leftover whose staleness keeps growing."""
    srv = _mk_fedbuff()
    applied = []
    monkeypatch.setattr(
        srv, "_apply",
        lambda batch: applied.append([r["_staleness"] for r in batch]))

    srv.aggregation(_results(3, srv.params))          # buffer: 3 < K
    assert applied == []
    assert len(srv._buffer) == 3
    # fresh this round: 0, or 1 for the slower-than-median stragglers
    # (aging happens when the *next* round arrives, so a finalize() flush
    # in the arrival round is not over-discounted)
    assert {r["_staleness"] for r in srv._buffer} == {0, 1}

    srv.aggregation(_results(3, srv.params))          # 6 >= K: one batch of 5
    assert len(applied) == 1 and len(applied[0]) == 5
    assert len(srv._buffer) == 1                       # leftover carried
    leftover = srv._buffer[0]
    s0 = leftover["_staleness"]

    srv.aggregation(_results(3, srv.params))          # 4 < K: defers again
    assert len(applied) == 1
    assert leftover["_staleness"] == s0 + 1            # ages per round held

    srv.finalize()                                     # end-of-training flush
    assert len(applied) == 2 and len(applied[1]) == 4
    assert srv._buffer == []


def test_fedbuff_deferred_round_leaves_params_unchanged():
    srv = _mk_fedbuff()
    before = jax.tree_util.tree_map(np.asarray, srv.params)
    srv.aggregation(_results(3, srv.params))           # 3 < K=5: no apply
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(srv.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    srv.finalize()                                     # flush applies now
    assert srv._buffer == []


def test_remote_server_run_flushes_buffered_aggregators(monkeypatch):
    """RemoteServer.run must finalize() the server so FedBuff leftovers
    are not dropped in the service deployment path."""
    from repro.core.remote import RemoteServer

    srv = _mk_fedbuff()
    rs = RemoteServer(srv, srv.cfg)
    monkeypatch.setattr(rs, "run_round",
                        lambda r: srv.aggregation(_results(3, srv.params)))
    flushed = []
    monkeypatch.setattr(srv, "_apply", lambda batch: flushed.append(len(batch)))
    rs.run(rounds=1)
    assert flushed == [3]          # 3 < K=5 deferred, finalize flushed them


def test_fedbuff_end_to_end_still_trains():
    """Sub-K cohorts (3/round vs K=5) through the full runtime: updates
    defer across rounds, finalize flushes, training still converges."""
    easyfl.reset()
    easyfl.init({
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 9, "batch_size": 32},
        "server": {"rounds": 6, "clients_per_round": 3},
        "client": {"local_epochs": 2, "lr": 0.1},
    })
    easyfl.register_server(FedBuffServer)
    res = easyfl.run()
    accs = [h["accuracy"] for h in res["history"]]
    assert accs[-1] > accs[0]
    easyfl.reset()
