"""RWKV6 time-mix with the Pallas kernel path (use_kernel=True, interpret)
must match the pure-jnp chunked path end to end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import init_params


def test_time_mix_kernel_matches_jnp_path():
    cfg = get_arch("rwkv6-1.6b", reduced=True)
    defs = rwkv_mod.rwkv_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    B, S = 2, 128
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    H = cfg.d_model // cfg.rwkv_head_dim
    s0 = jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim))
    x_prev = jnp.zeros((B, cfg.d_model))

    out_jnp, last_jnp, sT_jnp = rwkv_mod.time_mix(
        cfg, params["time"], x, x_prev, s0, use_kernel=False)
    out_k, last_k, sT_k = rwkv_mod.time_mix(
        cfg, params["time"], x, x_prev, s0, use_kernel=True)

    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_jnp),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sT_k), np.asarray(sT_jnp),
                               rtol=2e-3, atol=2e-3)


def test_time_mix_decode_continues_training_state():
    """Running time_mix over S tokens then decoding token S+1 must equal
    running time_mix over S+1 tokens (state handoff correctness)."""
    cfg = get_arch("rwkv6-1.6b", reduced=True)
    defs = rwkv_mod.rwkv_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    B, S = 1, 65
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.5
    H = cfg.d_model // cfg.rwkv_head_dim
    s0 = jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim))
    x_prev = jnp.zeros((B, cfg.d_model))

    out_full, _, _ = rwkv_mod.time_mix(cfg, params["time"], x, x_prev, s0)
    out_pre, last, sT = rwkv_mod.time_mix(
        cfg, params["time"], x[:, :-1], x_prev, s0)
    out_dec, _, _ = rwkv_mod.time_mix_decode(
        cfg, params["time"], x[:, -1:], last, sT)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
