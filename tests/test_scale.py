"""Million-client population machinery (docs/scale.md).

* TieredRowStore: LRU eviction order, host-spill -> bit-identical reload,
  cohort assembly spanning hot / spilled / never-seen clients, bounded
  device tier, tier-agnostic state round-trips.
* Hierarchical streaming aggregation: 1e-6 equality with flat FedAvg at
  every fanout, bit-equality when fanout >= cohort, composition with
  staleness weights, end-to-end topology parity.
* Virtual populations: O(k) id-space sampling, deterministic shard
  regeneration, auto/on/off policy, a 10^6-client round on one host.
* Checkpoint/resume with spilled EF residuals stays bit-identical.
* init() flat-key folding + register_dataset symmetry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro as easyfl
from repro.core.config import Config, validate_config
from repro.core.rounds import Trainer
from repro.core.server import Server
from repro.core.tiered_store import TieredRowStore
from repro.data.fed_data import (
    ClientIdSpace, VirtualFederatedDataset, build_federated_data,
)
from repro.data.synthetic import make_client_shard
from repro.kernels.fedavg_agg import fedavg_aggregate_tree
from repro.models.registry import get_model


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def _params_equal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a), _leaves(b)))


def _row(cid, d=4):
    rng = np.random.RandomState(abs(hash(cid)) % (2**31))
    return [rng.randn(d).astype(np.float32),
            rng.randn(d, 2).astype(np.float32)]


# ---------------------------------------------------------------------------
# TieredRowStore
# ---------------------------------------------------------------------------


def test_store_lru_eviction_order():
    st = TieredRowStore(3, spill="drop")
    st.ensure(["a", "b", "c"], _row)
    st.ensure(["a"], _row)                 # refresh a: LRU order b, c, a
    st.ensure(["d"], _row)                 # evicts b (least recent)
    assert set(st.rows) == {"a", "c", "d"}
    st.ensure(["e"], _row)                 # evicts c
    assert set(st.rows) == {"a", "d", "e"}
    assert st.stats["evictions"] == 2


def test_store_host_spill_reloads_bit_identically():
    st = TieredRowStore(2, spill="host")
    first = [np.array(l[0]) for l in st.gather(["a"], _row)]
    st.ensure(["b", "c"], _row)            # a spilled to host
    assert "a" not in st.rows and "a" in st
    assert list(st.spilled_ids()) == ["a"]
    again = [np.array(l[0]) for l in st.gather(["a"], _row)]
    for x, y in zip(first, again):
        np.testing.assert_array_equal(x, y)
    assert st.stats["reloads"] == 1 and st.stats["recomputes"] == 3


def test_store_cohort_spans_hot_spilled_and_never_seen():
    st = TieredRowStore(4, spill="host")
    st.ensure(["a", "b"], _row)            # hot
    st.ensure(["c", "d", "e", "f"], _row)  # spills a, b
    made = []
    leaves = st.gather(["e", "a", "zz"],   # hot + spilled + never-seen
                       lambda cid: made.append(cid) or _row(cid))
    assert made == ["zz"]                  # only the cold client recomputes
    for li, leaf in enumerate(leaves):
        np.testing.assert_array_equal(np.array(leaf[0]), _row("e")[li])
        np.testing.assert_array_equal(np.array(leaf[1]), _row("a")[li])
        np.testing.assert_array_equal(np.array(leaf[2]), _row("zz")[li])


def test_store_device_tier_is_bounded_but_pins_cohort():
    st = TieredRowStore(4, spill="drop")
    for i in range(20):
        st.ensure([f"c{i}"], _row)
    assert len(st.rows) <= 4 and st.alloc <= 4
    # a cohort larger than capacity pins the tier open for the round
    big = [f"big{i}" for i in range(7)]
    st.ensure(big, _row)
    assert set(big) <= set(st.rows)
    bytes_before = st.device_bytes()
    for i in range(10):
        st.ensure([f"later{i}"], _row)
    assert st.device_bytes() <= bytes_before    # never grows past max seen


def test_store_state_roundtrip_is_tier_agnostic():
    st = TieredRowStore(2, spill="host")
    ids = [f"c{i}" for i in range(6)]
    for cid in ids:
        st.ensure([cid], _row)             # most spilled, some hot
    snap = st.state()
    assert set(snap["clients"]) == set(ids)
    st2 = TieredRowStore(3, spill="host")  # different device-tier size
    st2.load_state(snap)
    for cid in ids:
        got = [np.array(l[0]) for l in st2.gather([cid], _row)]
        for x, y in zip(got, _row(cid)):
            np.testing.assert_array_equal(x, y)


def test_store_rejects_bad_args():
    with pytest.raises(ValueError, match="spill"):
        TieredRowStore(4, spill="nope")
    with pytest.raises(ValueError, match="capacity"):
        TieredRowStore(0)


# ---------------------------------------------------------------------------
# hierarchical streaming aggregation
# ---------------------------------------------------------------------------


def _updates(n=100, d=257, seed=0):
    rng = np.random.RandomState(seed)
    u = jnp.asarray(rng.randn(n, d).astype(np.float32))
    w = rng.rand(n).astype(np.float64)
    return u, jnp.asarray((w / w.sum()).astype(np.float32))


@pytest.mark.parametrize("fanout", [0, 2, 5, 16])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_tree_matches_flat_within_tolerance(fanout, use_kernel):
    u, w = _updates()
    flat = np.asarray(jnp.einsum("n,nd->d", w, u))
    tree = np.asarray(fedavg_aggregate_tree(
        u, w, fanout=fanout, use_kernel=use_kernel, interpret=True))
    np.testing.assert_allclose(tree, flat, atol=1e-6)


def test_tree_bit_equal_to_flat_when_fanout_covers_cohort():
    u, w = _updates(n=40)
    for fanout in (40, 64, 1000):
        tree = np.asarray(fedavg_aggregate_tree(
            u, w, fanout=fanout, use_kernel=False))
        np.testing.assert_array_equal(
            tree, np.asarray(jnp.einsum("n,nd->d", w, u)))


def test_tree_composes_with_staleness_weights():
    from repro.kernels.fedavg_agg import fold_staleness
    u, w = _updates(n=30)
    s = jnp.asarray(np.random.RandomState(1).randint(0, 5, 30), jnp.float32)
    folded = fold_staleness(w, s, 0.5)
    flat = np.asarray(jnp.einsum("n,nd->d", folded, u))
    tree = np.asarray(fedavg_aggregate_tree(
        u, w, fanout=4, use_kernel=False, staleness=s, staleness_power=0.5))
    np.testing.assert_allclose(tree, flat, atol=1e-6)


def test_invalid_topology_and_fanout_rejected_at_init():
    with pytest.raises(ValueError, match="aggregation_topology"):
        validate_config(Config.make(
            {"resources": {"aggregation_topology": "ring"}}))
    with pytest.raises(ValueError, match="aggregation_fanout"):
        validate_config(Config.make(
            {"resources": {"aggregation_fanout": 1}}))


def _topology_trainer(topology, fanout=0, execution="batched"):
    cfg = Config.make({
        "model": "linear",
        "data": {"dataset": "synthetic", "num_clients": 12,
                 "batch_size": 32},
        "server": {"rounds": 2, "clients_per_round": 6, "test_every": 0},
        "client": {"local_epochs": 1, "lr": 0.1},
        "resources": {"execution": execution,
                      "aggregation_topology": topology,
                      "aggregation_fanout": fanout},
        "tracking": {"enabled": False},
    })
    model = get_model("linear")
    fed = build_federated_data(cfg.data)
    t = Trainer(cfg, model, fed, server=Server(model, cfg, fed.test))
    t.server.params = model.init(jax.random.PRNGKey(cfg.seed))
    return t


@pytest.mark.parametrize("execution", ["batched", "sequential"])
def test_end_to_end_topology_parity(execution):
    """fanout >= cohort short-circuits to the flat program: a whole run
    under the hierarchical knob is bit-identical to flat."""
    flat = _topology_trainer("flat", execution=execution).run()
    tree = _topology_trainer("hierarchical", fanout=64,
                             execution=execution).run()
    assert _params_equal(flat["params"], tree["params"])


def test_end_to_end_hierarchical_close_to_flat():
    flat = _topology_trainer("flat").run()
    tree = _topology_trainer("hierarchical", fanout=2).run()
    for x, y in zip(_leaves(flat["params"]), _leaves(tree["params"])):
        np.testing.assert_allclose(x, y, atol=1e-5)


# ---------------------------------------------------------------------------
# virtual populations
# ---------------------------------------------------------------------------


def test_id_space_sampling_is_o_k_and_excludes():
    s = ClientIdSpace(1_000_000)
    assert len(s) == 1_000_000 and s[7] == "client_0007"
    assert "client_999999" in s and "client_1000000" not in s
    rng = np.random.RandomState(0)
    a = s.sample(rng, 100)
    b = s.sample(rng, 100, exclude=set(a))
    assert len(set(a)) == 100 and not set(a) & set(b)
    # same rng state -> same draw (selection determinism)
    c = ClientIdSpace(1_000_000).sample(np.random.RandomState(0), 100)
    assert a == c
    # small spaces fall back to a complement draw and still exclude
    tiny = ClientIdSpace(6)
    got = tiny.sample(np.random.RandomState(1), 10, exclude={"client_0002"})
    assert sorted(got) == [f"client_{i:04d}" for i in (0, 1, 3, 4, 5)]


def test_virtual_shards_regenerate_bit_identically():
    fed = VirtualFederatedDataset("synthetic", 1_000_000, seed=3)
    a, b = fed.clients["client_424242"], fed.clients["client_424242"]
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)
    x, y = make_client_shard("synthetic", 424242, 0, seed=3)
    np.testing.assert_array_equal(a.x, x)
    with pytest.raises(KeyError):
        fed.clients["client_9999999"]
    assert fed.stats()["num_clients"] == 1_000_000


def test_virtual_policy_auto_on_off():
    from repro.core.config import DataConfig
    small = build_federated_data(
        DataConfig(dataset="synthetic", num_clients=100))
    assert not isinstance(small, VirtualFederatedDataset)
    auto = build_federated_data(
        DataConfig(dataset="synthetic", num_clients=50_000))
    assert isinstance(auto, VirtualFederatedDataset)
    off = build_federated_data(
        DataConfig(dataset="synthetic", num_clients=100, virtual="off",
                   samples_per_client=0))
    assert not isinstance(off, VirtualFederatedDataset)
    forced = build_federated_data(
        DataConfig(dataset="femnist", num_clients=100, virtual="on"))
    assert isinstance(forced, VirtualFederatedDataset)
    with pytest.raises(ValueError, match="virtual"):
        build_federated_data(
            DataConfig(dataset="shakespeare", num_clients=100, virtual="on"))


def test_million_client_round_runs_on_one_host():
    easyfl.reset()
    try:
        easyfl.init({
            "model": "linear",
            "data": {"dataset": "synthetic", "num_clients": 1_000_000,
                     "batch_size": 32},
            "server": {"rounds": 2, "clients_per_round": 100,
                       "test_every": 0},
            "client": {"local_epochs": 1, "lr": 0.1},
            "resources": {"execution": "batched",
                          "aggregation_topology": "hierarchical"},
            "tracking": {"enabled": False},
        })
        res = easyfl.run()
        assert res["rounds"] == 2
        assert np.isfinite(res["final"]["train_loss"])
    finally:
        easyfl.reset()


def test_heterogeneity_is_stateless_but_honors_overrides():
    from repro.core.config import SystemHeterogeneityConfig
    from repro.simulation.heterogeneity import SystemHeterogeneity
    het = SystemHeterogeneity(SystemHeterogeneityConfig(enabled=True))
    r1 = het.speed_ratio("client_0042")
    assert het.assignment == {}            # nothing cached, O(1) memory
    assert het.speed_ratio("client_0042") == r1
    het2 = SystemHeterogeneity(SystemHeterogeneityConfig(enabled=True))
    assert het2.speed_ratio("client_0042") == r1   # process-stable
    het.assignment["client_0042"] = 99.0   # explicit override wins
    assert het.speed_ratio("client_0042") == 99.0


def test_tracking_client_history_retention():
    from repro.tracking import Tracker
    t = Tracker(client_history_rounds=2)
    for r in range(5):
        t.track_round("task", r, loss=float(r))
        t.track_client("task", r, "c0", loss=float(r))
    task = t.get_task("task")
    assert sorted(task.rounds) == [0, 1, 2, 3, 4]   # round level kept
    kept = [r for r in task.rounds if task.rounds[r].clients]
    assert kept == [3, 4]
    assert t.round_series("task", "loss") == [0.0, 1.0, 2.0, 3.0, 4.0]


# ---------------------------------------------------------------------------
# checkpoint/resume with spilled EF residuals
# ---------------------------------------------------------------------------


def test_resume_with_spilled_ef_residuals_bit_identical(tmp_path,
                                                        monkeypatch):
    """With the EF device tier capped below the touched-client count,
    residuals spill to the host mid-run; a kill-and-resume must still be
    bit-identical to the uninterrupted run."""
    from repro.core.batched import BatchedExecutor

    monkeypatch.setattr(BatchedExecutor, "EF_MAX_CLIENTS", 3)

    def make(d):
        cfg = Config.make({
            "model": "linear",
            "data": {"dataset": "synthetic", "num_clients": 10,
                     "batch_size": 32},
            "server": {"rounds": 4, "clients_per_round": 5,
                       "test_every": 0},
            "client": {"local_epochs": 1, "lr": 0.1, "compression": "stc"},
            "resources": {"execution": "batched"},
            "tracking": {"enabled": False},
            "checkpoint": {"every": 2, "dir": d},
        })
        model = get_model("linear")
        fed = build_federated_data(cfg.data)
        t = Trainer(cfg, model, fed, server=Server(model, cfg, fed.test))
        t.server.params = model.init(jax.random.PRNGKey(cfg.seed))
        return t

    ra = make(str(tmp_path / "A")).run()
    tb = make(str(tmp_path / "B"))
    assert tb.engine.EF_MAX_CLIENTS == 3
    for r in range(2):
        tb.run_round(r)
        tb._maybe_checkpoint(r + 1)
    assert len(tb.engine._ef._host) > 0    # spill actually happened
    rc = make(str(tmp_path / "B")).resume()
    assert _params_equal(ra["params"], rc["params"])


# ---------------------------------------------------------------------------
# low-code config surface
# ---------------------------------------------------------------------------


def test_init_folds_any_unambiguous_flat_key():
    easyfl.reset()
    try:
        cfg = easyfl.init({
            "dataset": "synthetic", "num_clients": 8,
            "clients_per_round": 4, "local_epochs": 1, "lora_rank": 0,
            "aggregation_topology": "hierarchical", "rounds": 2,
        })
        assert cfg.data.dataset == "synthetic"
        assert cfg.data.num_clients == 8
        assert cfg.server.clients_per_round == 4
        assert cfg.server.rounds == 2
        assert cfg.client.local_epochs == 1
        assert cfg.resources.aggregation_topology == "hierarchical"
    finally:
        easyfl.reset()


def test_init_flat_key_ambiguity_names_candidates():
    easyfl.reset()
    try:
        with pytest.raises(KeyError, match=r"server\.compression"):
            easyfl.init({"dataset": "synthetic", "compression": "stc"})
        with pytest.raises(KeyError, match=r"client\.compression"):
            easyfl.init({"dataset": "synthetic", "compression": "stc"})
        with pytest.raises(KeyError, match="conflicts"):
            easyfl.init({"dataset": "synthetic",
                         "data": {"dataset": "femnist"}})
        with pytest.raises(KeyError, match="unknown config key"):
            easyfl.init({"datsaet": "synthetic"})
    finally:
        easyfl.reset()


def test_register_dataset_requires_name_and_adopts_test():
    from repro.data.synthetic import RawDataset
    easyfl.reset()
    try:
        rng = np.random.RandomState(0)
        raw = RawDataset(rng.randn(200, 64).astype(np.float32),
                         rng.randint(0, 10, 200).astype(np.int32), 10)
        with pytest.raises(ValueError, match="name"):
            easyfl.register_dataset(raw)
        held = RawDataset(np.zeros((50, 64), np.float32),
                          np.zeros(50, np.int32), 10)
        easyfl.register_dataset(raw, test=held, name="mydata")
        easyfl.init({"dataset": "mydata", "model": "linear",
                     "data": {"num_clients": 5},
                     "server": {"rounds": 1, "clients_per_round": 2}})
        from repro.core.api import _ctx
        assert len(_ctx.fed_data.test.x) == 50       # adopted split
        assert _ctx.fed_data.stats()["total_samples"] == 200  # all trained
        assert easyfl.run()["rounds"] == 1
    finally:
        easyfl.reset()
