"""Wire-protocol roundtrip properties (paper Fig. 4a Protocol tier)."""
import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.comm import serialize


@given(hnp.arrays(dtype=st.sampled_from([np.float32, np.float64, np.int32,
                                         np.int8, np.uint8, np.bool_]),
                  shape=hnp.array_shapes(max_dims=4, max_side=16)))
@settings(max_examples=40, deadline=None)
def test_ndarray_roundtrip(arr):
    out = serialize.loads(serialize.dumps(arr))
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


def test_nested_pytree_roundtrip():
    tree = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.zeros(4, dtype=np.float32)},
        "meta": {"round": 3, "lr": 0.1, "name": "client_0001",
                 "tags": ["a", "b"], "tuple": (1, 2.5, "x")},
        "flag": True,
        "none": None,
    }
    out = serialize.loads(serialize.dumps(tree))
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    assert out["meta"]["tuple"] == (1, 2.5, "x")
    assert out["meta"]["round"] == 3
    assert out["flag"] is True
    assert out["none"] is None


def test_jax_arrays_serializable():
    import jax.numpy as jnp
    tree = {"w": jnp.ones((8, 8), jnp.bfloat16) * 2}
    out = serialize.loads(serialize.dumps(tree))
    # bf16 roundtrips via its numpy extension dtype
    assert out["w"].shape == (8, 8)
    assert float(out["w"][0, 0]) == 2.0


def test_message_bytes_tracks_size():
    small = serialize.message_bytes({"w": np.zeros(10, np.float32)})
    large = serialize.message_bytes({"w": np.zeros(1000, np.float32)})
    assert large > small
    assert large >= 4000
