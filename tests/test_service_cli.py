"""Container-entrypoint services (repro.launch.service): a full multi-role
deployment on localhost — registry + tracker + N clients + server — the
paper's production topology (Fig. 4) end to end."""
import json

import pytest

import repro as easyfl
from repro.launch import service as svc


@pytest.fixture(autouse=True)
def _reset():
    easyfl.reset()
    yield
    easyfl.reset()


def test_full_deployment_topology():
    cfg_json = json.dumps({
        "model": "linear", "dataset": "synthetic",
        "data": {"num_clients": 3, "batch_size": 32},
        "server": {"rounds": 2, "clients_per_round": 2},
        "client": {"local_epochs": 1, "lr": 0.1},
    })
    registry = svc.main(["registry", "--oneshot"])
    tracker = svc.main(["tracker", "--oneshot"])
    reg_addr = f"{registry.address[0]}:{registry.address[1]}"
    trk_addr = f"{tracker.address[0]}:{tracker.address[1]}"
    clients = []
    try:
        for i in range(3):
            clients.append(svc.main([
                "client", "--client-id", f"client_{i:04d}",
                "--registry", reg_addr, "--config", cfg_json, "--oneshot"]))
        # discovery sees all clients
        names = sorted(r.client_id for r in
                       svc.RemoteRegistry(svc._parse_addr(reg_addr)).list())
        assert names == ["client_0000", "client_0001", "client_0002"]

        server = svc.main(["server", "--registry", reg_addr,
                           "--tracker", trk_addr, "--config", cfg_json,
                           "--rounds", "2", "--oneshot"])
        assert len(server.history) == 2
        assert server.history[-1]["accuracy"] > 0.2
        # remote tracking captured the rounds
        rt = svc.RemoteTracker(svc._parse_addr(trk_addr))
        series = rt.round_series(server.cfg.task_id, "accuracy")
        assert len(series) == 2
        rt.close()
    finally:
        for c in clients:
            c.stop()
        registry.stop()
        tracker.stop()


def test_registry_service_roundtrip():
    registry = svc.main(["registry", "--oneshot"])
    try:
        rr = svc.RemoteRegistry(registry.address)
        rr.register("cX", ("10.0.0.1", 5555), role="client")
        assert rr.heartbeat("cX")
        regs = rr.list()
        assert regs[0].address == ("10.0.0.1", 5555)
        rr.deregister("cX")
        assert rr.list() == []
        rr.close()
    finally:
        registry.stop()
