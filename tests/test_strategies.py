"""Strategy plugins: FedProx (train stage), STC (compression stages),
FedReID (train + aggregation semantics), heterogeneity + data manager."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import Client
from repro.core.config import ClientConfig, DataConfig
from repro.core.strategies import FedProxClient, FedReIDClient, STCClient
from repro.data import ClientData, build_federated_data
from repro.models.registry import get_model
from repro.simulation.heterogeneity import SystemHeterogeneity, straggler_stats
from repro.core.config import SystemHeterogeneityConfig


def _client_data(n=64, d=64, seed=0):
    rng = np.random.RandomState(seed)
    return ClientData(rng.randn(n, d).astype(np.float32),
                      rng.randint(0, 10, n).astype(np.int32))


def _payload(model, key=0):
    params = model.init(jax.random.PRNGKey(key))
    return {"params": params}, params


def test_fedprox_shrinks_update_norm():
    """Large mu must pull client updates toward the global model."""
    model = get_model("linear")
    data = _client_data()
    payload, params = _payload(model)

    def update_norm(mu):
        cfg = ClientConfig(local_epochs=3, lr=0.1, proximal_mu=mu)
        if mu == 0.0:
            c = Client("c0", model, data, cfg, batch_size=32)
        else:
            c = FedProxClient("c0", model, data, cfg, batch_size=32, mu=mu)
        res = c.run_round(payload, 0)
        return float(sum(jnp.sum(jnp.square(u))
                         for u in jax.tree_util.tree_leaves(res["update"])))

    assert update_norm(1.0) < update_norm(0.0)


def test_stc_client_sends_sparse_and_keeps_residual():
    model = get_model("linear")
    cfg = ClientConfig(local_epochs=2, lr=0.2, stc_sparsity=0.05)
    c = STCClient("c0", model, _client_data(), cfg, batch_size=32)
    payload, _ = _payload(model)
    res = c.run_round(payload, 0)
    from repro.core.compression import CompressedTensor, decompress
    leaves = jax.tree_util.tree_leaves(
        res["update"], is_leaf=lambda x: isinstance(x, CompressedTensor))
    assert any(isinstance(l, CompressedTensor) for l in leaves)
    assert res["payload_bytes"] > 0
    assert c._residual is not None
    dense = decompress(res["update"])
    frac = np.mean([(np.asarray(x) != 0).mean()
                    for x in jax.tree_util.tree_leaves(dense)
                    if np.asarray(x).size > 64])
    assert frac < 0.2


def test_fedreid_keeps_local_head_out_of_aggregation():
    model = get_model("femnist_cnn")
    cfg = ClientConfig(local_epochs=1, lr=0.1)
    rng = np.random.RandomState(0)
    data = ClientData(rng.randn(32, 784).astype(np.float32),
                      rng.randint(0, 62, 32).astype(np.int32))
    c = FedReIDClient("c0", model, data, cfg, batch_size=16)
    payload, _ = _payload(model)
    res = c.run_round(payload, 0)
    assert float(jnp.abs(res["update"]["fc2"]["w"]).max()) == 0.0
    assert float(jnp.abs(res["update"]["conv1"]["w"]).max()) > 0.0


def test_system_heterogeneity_deterministic_assignment():
    het = SystemHeterogeneity(SystemHeterogeneityConfig(enabled=True, seed=1))
    r1 = het.speed_ratio("client_0001")
    r2 = het.speed_ratio("client_0001")
    assert r1 == r2
    het2 = SystemHeterogeneity(SystemHeterogeneityConfig(enabled=True, seed=1))
    assert het2.speed_ratio("client_0001") == r1
    ratios = {het.speed_ratio(f"client_{i:04d}") for i in range(50)}
    assert len(ratios) > 1      # multiple device classes in play


def test_straggler_stats():
    s = straggler_stats({"a": 1.0, "b": 4.0, "c": 2.0})
    assert s["max_over_min"] == pytest.approx(4.0)


def test_data_manager_realistic_partition():
    cfg = DataConfig(dataset="femnist", num_clients=20, partition="realistic",
                     seed=0)
    fed = build_federated_data(cfg)
    assert len(fed.clients) == 20
    assert fed.num_classes == 62
    assert len(fed.test) > 0


def test_data_amount_scaling():
    """Fig. 7b knob: data_amount shrinks total training samples."""
    full = build_federated_data(DataConfig(dataset="synthetic",
                                           num_clients=10, data_amount=1.0))
    frac = build_federated_data(DataConfig(dataset="synthetic",
                                           num_clients=10, data_amount=0.2))
    assert frac.stats()["total_samples"] < 0.3 * full.stats()["total_samples"]


def test_unbalanced_partition_spread():
    cfg = DataConfig(dataset="synthetic", num_clients=10, partition="iid",
                     unbalanced=True, unbalanced_sigma=1.2)
    fed = build_federated_data(cfg)
    st = fed.stats()
    assert st["max"] > 2 * st["min"]
