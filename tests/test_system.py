"""End-to-end behaviour tests: the paper's headline workflows."""
import numpy as np
import pytest

import repro as easyfl


@pytest.fixture(autouse=True)
def _reset():
    easyfl.reset()
    yield
    easyfl.reset()


def _base_cfg(**over):
    cfg = {
        "model": "linear",
        "dataset": "synthetic",
        "data": {"num_clients": 12, "batch_size": 32},
        "server": {"rounds": 3, "clients_per_round": 4},
        "client": {"local_epochs": 2, "lr": 0.1},
    }
    for k, v in over.items():
        if isinstance(v, dict) and k in cfg:
            cfg[k] = {**cfg[k], **v}
        else:
            cfg[k] = v
    return cfg


def test_three_line_quickstart():
    """Paper Listing 1 Example 1: init + run is a complete FL app."""
    easyfl.init(_base_cfg())
    result = easyfl.run()
    assert result["rounds"] == 3
    assert len(result["history"]) == 3
    assert "accuracy" in result["history"][-1]


def test_training_improves_accuracy():
    easyfl.init(_base_cfg(server={"rounds": 5}))
    result = easyfl.run()
    accs = [h["accuracy"] for h in result["history"]]
    assert accs[-1] > accs[0], accs
    assert accs[-1] > 0.5


def test_tracking_hierarchy_populated():
    cfg = easyfl.init(_base_cfg())
    easyfl.run()
    tr = easyfl.tracker()
    task = tr.get_task(cfg.task_id)
    assert len(task.rounds) == 3
    rnd = task.rounds[0]
    assert len(rnd.clients) == 4                 # client level
    assert "round_time" in rnd.metrics           # round level
    assert task.config["server"]["rounds"] == 3  # task level
    assert len(tr.round_series(cfg.task_id, "accuracy")) == 3


def test_heterogeneity_round_time_varies():
    """System heterogeneity must produce stragglers (paper Fig. 6b)."""
    cfg = easyfl.init(_base_cfg(
        server={"clients_per_round": 8},
        system_heterogeneity={"enabled": True},
        resources={"num_devices": 2, "allocation": "greedy_ada"},
    ))
    easyfl.run()
    times = easyfl.tracker().client_series(cfg.task_id, 1, "simulated_time")
    assert len(set(round(t, 6) for t in times.values())) > 1


def test_custom_client_registration():
    from repro.core.client import Client

    calls = []

    class MyClient(Client):
        def train(self, params, round_id):
            calls.append(round_id)
            return super().train(params, round_id)

    easyfl.init(_base_cfg(server={"rounds": 2}))
    easyfl.register_client(MyClient)
    easyfl.run()
    assert sorted(set(calls)) == [0, 1]


def test_custom_server_registration():
    from repro.core.server import Server

    class MyServer(Server):
        def selection(self, client_ids, round_id):
            return sorted(client_ids)[:2]   # deterministic selection stage

    easyfl.init(_base_cfg())
    easyfl.register_server(MyServer)
    res = easyfl.run()
    assert res["history"][0]["clients"] == 2


def test_greedyada_beats_slowest_allocation():
    """End-to-end scheduling comparison.  Client wall times on a 1-core
    container are ms-scale and noisy, so: unbalanced data for real spread,
    the paper's m=1 profiling mode (§VI), warmup rounds skipped, and a
    noise-tolerant margin (the precise LPT guarantees are property-tested
    deterministically in test_greedyada.py)."""
    results = {}
    for alloc in ("greedy_ada", "slowest"):
        easyfl.reset()
        easyfl.init(_base_cfg(
            task_id=f"alloc_{alloc}",
            data={"num_clients": 16, "unbalanced": True,
                  "unbalanced_sigma": 1.4},
            server={"rounds": 6, "clients_per_round": 10},
            client={"local_epochs": 2, "lr": 0.1},
            system_heterogeneity={"enabled": True},
            resources={"num_devices": 4, "allocation": alloc,
                       "momentum": 1.0},
        ))
        res = easyfl.run()
        results[alloc] = np.mean([h["round_time"] for h in res["history"][2:]])
    assert results["greedy_ada"] <= results["slowest"] * 1.15, results


def test_remote_training_socket_roundtrip():
    """Paper Listing 1 Example 2: start_server/start_client services."""
    easyfl.init(_base_cfg(data={"num_clients": 3},
                          server={"rounds": 2, "clients_per_round": 2},
                          client={"local_epochs": 1, "lr": 0.1}))
    clients = [easyfl.start_client({"client_id": f"client_{i:04d}"})
               for i in range(3)]
    server = easyfl.start_server()
    try:
        hist = server.run(2)
        assert len(hist) == 2
        assert "accuracy" in hist[-1]
    finally:
        for c in clients:
            c.stop()
        server.stop()


def test_register_external_dataset():
    from repro.data import ClientData, FederatedDataset

    rng = np.random.RandomState(0)
    clients = {f"client_{i:04d}": ClientData(
        rng.randn(40, 64).astype(np.float32),
        rng.randint(0, 10, 40).astype(np.int32)) for i in range(4)}
    test = ClientData(rng.randn(50, 64).astype(np.float32),
                      rng.randint(0, 10, 50).astype(np.int32))
    fed = FederatedDataset(clients, test, 10)

    easyfl.init(_base_cfg(data={"num_clients": 4}))
    easyfl.register_dataset(fed)
    res = easyfl.run()
    assert res["rounds"] == 3
