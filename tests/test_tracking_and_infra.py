"""Tracking manager, service discovery, checkpointing, deployment
manifests, transports."""
import os
import time

import numpy as np
import pytest
import yaml

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.comm.transport import (
    InProcessTransport, RPCServer, SocketTransport, parallel_requests,
)
from repro.deploy.discovery import Registor, Registry
from repro.deploy.manifests import compose, dockerfile, k8s_manifests, write_artifacts
from repro.tracking import Tracker


# ---------------------------------------------------------------------------
# tracking (paper §V-C: task -> round -> client)
# ---------------------------------------------------------------------------


def test_tracker_three_levels_and_queries():
    t = Tracker()
    t.create_task("t1", {"lr": 0.1})
    for r in range(3):
        t.track_round("t1", r, accuracy=0.5 + 0.1 * r, round_time=1.0)
        for c in range(2):
            t.track_client("t1", r, f"c{c}", loss=1.0 - 0.1 * r)
    assert t.round_series("t1", "accuracy") == pytest.approx([0.5, 0.6, 0.7])
    assert t.best_round("t1", "accuracy") == 2
    assert len(t.client_series("t1", 1, "loss")) == 2
    assert t.summary("t1")["rounds"] == 3


def test_tracker_jsonl_persistence(tmp_path):
    t = Tracker(backend="jsonl", out_dir=str(tmp_path))
    t.create_task("t1", {})
    t.track_round("t1", 0, accuracy=0.9)
    t.track_client("t1", 0, "c0", loss=0.5)
    t2 = Tracker.load_jsonl(str(tmp_path))
    assert t2.round_series("t1", "accuracy") == pytest.approx([0.9])
    assert t2.client_series("t1", 0, "loss")["c0"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# service discovery (paper Fig. 4b)
# ---------------------------------------------------------------------------


def test_registry_register_lookup_deregister():
    reg = Registry()
    reg.register("c0", ("127.0.0.1", 5000), role="client")
    assert reg.lookup("c0").address == ("127.0.0.1", 5000)
    assert len(reg.list()) == 1
    reg.deregister("c0")
    assert reg.lookup("c0") is None


def test_registry_ttl_expiry():
    reg = Registry(default_ttl=0.05)
    reg.register("c0", ("127.0.0.1", 5000))
    assert reg.lookup("c0") is not None
    time.sleep(0.08)
    assert reg.lookup("c0") is None     # dropped out (paper: clients churn)
    reg.register("c1", ("127.0.0.1", 5001))
    assert reg.heartbeat("c1")
    assert not reg.heartbeat("c0")


def test_registry_watch_events():
    reg = Registry()
    events = []
    reg.watch(lambda cid, r: events.append((cid, r is not None)))
    reg.register("c0", ("h", 1))
    reg.deregister("c0")
    assert events == [("c0", True), ("c0", False)]


def test_registor_registers_service():
    reg = Registry()
    r = Registor(reg)
    r.register_service("c9", ("10.0.0.9", 1234), role="client")
    assert reg.lookup("c9").metadata["role"] == "client"


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": np.arange(10, dtype=np.float32), "step": 7}
    for s in (1, 2, 3, 4):
        save_checkpoint(d, tree, s, keep=2)
    assert latest_step(d) == 4
    out = load_checkpoint(d)
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert sorted(os.listdir(d)) == ["ckpt_00000003.msgpack",
                                     "ckpt_00000004.msgpack"]


# ---------------------------------------------------------------------------
# deployment manifests
# ---------------------------------------------------------------------------


def test_manifests_structurally_valid(tmp_path):
    assert "pip install" in dockerfile()
    c = compose(num_clients=3, network_latency_ms=20)
    assert len([s for s in c["services"] if s.startswith("client")]) == 3
    assert "cap_add" in c["services"]["client0"]
    ms = k8s_manifests(num_clients=5)
    kinds = [m["kind"] for m in ms]
    assert kinds.count("Deployment") == 2
    client_dep = [m for m in ms if m["metadata"]["name"] == "easyfl-client"][0]
    assert client_dep["spec"]["replicas"] == 5
    env = client_dep["spec"]["template"]["spec"]["containers"][0]["env"]
    assert any(e["name"] == "POD_IP" for e in env)   # downward-API registor
    paths = write_artifacts(str(tmp_path), 2)
    for p in paths:
        assert os.path.exists(p)
    with open(os.path.join(str(tmp_path), "k8s.yaml")) as f:
        docs = list(yaml.safe_load_all(f))
    assert len(docs) == 3


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


def test_inprocess_transport_serializes_both_ways():
    tr = InProcessTransport(lambda m, p: {"echo": p["x"] * 2})
    out = tr.request("f", {"x": np.ones(4, np.float32)})
    np.testing.assert_array_equal(out["echo"], 2 * np.ones(4))
    assert tr.stats.bytes_sent > 0 and tr.stats.bytes_received > 0


def test_socket_transport_parallel_requests():
    srv = RPCServer(lambda m, p: {"sq": p["x"] ** 2}).start()
    try:
        trs = [SocketTransport(srv.address) for _ in range(3)]
        outs = parallel_requests(trs, "f", [{"x": i} for i in range(3)])
        assert [o["sq"] for o in outs] == [0, 1, 4]
        for t in trs:
            t.close()
    finally:
        srv.stop()
