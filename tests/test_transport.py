"""Failure paths and latency accounting for ``repro.comm.transport``.

The round runtimes treat a transport error as a client failure
(cfg.faults retry machinery), so the transports must fail *loudly and
typed*: ``ConnectionError`` for dead sockets, the handler's own
exception for application errors — never a silent empty response.
"""
import socket
import threading

import numpy as np
import pytest

from repro.comm.transport import (
    InProcessTransport, RPCServer, SocketTransport, _recv_exact,
    parallel_requests,
)


def _echo(method, payload):
    return {"method": method, "payload": payload}


# ---------------------------------------------------------------------------
# in-process transport
# ---------------------------------------------------------------------------


def test_inprocess_roundtrip_tracks_stats_and_latency():
    tr = InProcessTransport(_echo, latency=0.01)
    out = tr.request("train", {"x": np.arange(3, dtype=np.float32)})
    assert out["method"] == "train"
    np.testing.assert_array_equal(out["payload"]["x"],
                                  np.arange(3, dtype=np.float32))
    assert tr.stats.requests == 1
    assert tr.stats.bytes_sent > 0 and tr.stats.bytes_received > 0
    assert tr.stats.total_latency >= 0.01   # injected network latency


def test_inprocess_handler_error_propagates():
    def boom(method, payload):
        raise RuntimeError("client exploded mid-round")

    tr = InProcessTransport(boom)
    with pytest.raises(RuntimeError, match="exploded"):
        tr.request("train", {})
    # a failed request is not silently counted as delivered
    assert tr.stats.requests == 0


# ---------------------------------------------------------------------------
# socket transport against the RPC server
# ---------------------------------------------------------------------------


def test_socket_roundtrip_and_parallel_requests():
    server = RPCServer(_echo).start()
    try:
        trs = [SocketTransport(server.address) for _ in range(3)]
        outs = parallel_requests(trs, "ping", [{"i": i} for i in range(3)])
        assert [o["payload"]["i"] for o in outs] == [0, 1, 2]
        assert all(t.stats.requests == 1 for t in trs)
        for t in trs:
            t.close()
    finally:
        server.stop()


def test_server_dying_mid_request_raises_connection_error():
    """A server that accepts, reads part of the request, then dies: the
    client's reply stream ends mid-message and must surface as a
    ``ConnectionError`` — the typed signal the fault layer retries on."""
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)

    def drop():
        conn, _ = lsock.accept()
        conn.recv(16)
        conn.close()

    th = threading.Thread(target=drop, daemon=True)
    th.start()
    tr = SocketTransport(lsock.getsockname())
    try:
        with pytest.raises((ConnectionError, OSError)):
            tr.request("ping", {"i": 2})
    finally:
        tr.close()
        lsock.close()
        th.join(timeout=5)


def test_socket_request_after_local_close_raises():
    server = RPCServer(_echo).start()
    try:
        tr = SocketTransport(server.address)
        tr.close()
        with pytest.raises(OSError):
            tr.request("ping", {})
        tr.close()   # close is idempotent
    finally:
        server.stop()


def test_recv_exact_raises_on_truncated_stream():
    a, b = socket.socketpair()
    try:
        a.sendall(b"abc")
        a.close()               # stream ends before the 8 requested bytes
        with pytest.raises(ConnectionError, match="socket closed"):
            _recv_exact(b, 8)
    finally:
        b.close()


def test_socket_transport_is_thread_safe_under_contention():
    """The per-transport lock serializes request/reply pairs: concurrent
    callers on ONE socket must never interleave frames."""
    server = RPCServer(_echo).start()
    try:
        tr = SocketTransport(server.address)
        outs = [None] * 8

        def hit(i):
            outs[i] = tr.request("ping", {"i": i})

        threads = [threading.Thread(target=hit, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sorted(o["payload"]["i"] for o in outs) == list(range(8))
        assert tr.stats.requests == 8
        tr.close()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# simulated network latency (system_heterogeneity.network_latency)
# ---------------------------------------------------------------------------


def test_simulated_network_latency_adds_to_virtual_time():
    from repro.core.config import SystemHeterogeneityConfig
    from repro.simulation.heterogeneity import SystemHeterogeneity

    het = SystemHeterogeneity(
        SystemHeterogeneityConfig(enabled=True, network_latency=0.25))
    het.assignment["c0"] = 2.0
    assert het.simulate_time("c0", 1.0) == pytest.approx(2.25)
